//! Streaming shard pipeline: the scale-out ingestion path.
//!
//! Mirrors the paper's deployment shape at laptop scale: the edge stream is
//! partitioned over shard workers by the **same** `machine_of(min endpoint)`
//! hash the resident [`ShardedGraph`] is keyed by, each worker performs a
//! *local contraction* of its partition (streaming union-find — the same
//! primitive as the §6 finisher), and the much smaller **summary graph**
//! (one spanning edge per worker-local merge) is handed to a global
//! finisher — by default the paper's LocalContraction running on the MPC
//! simulator, with the compiled XLA dense backend when it fits a shard.
//!
//! Because routing is the ownership hash, worker `w`'s spanning edges *are*
//! shard `w` of the summary: the workers' outputs become the summary
//! [`ShardedGraph`] directly ([`ShardedGraph::from_shard_buckets`]), with
//! no concatenate-then-reshard round trip, and the finisher
//! ([`merge_summary`], or [`super::Driver::run_named_sharded`] for a paper
//! algorithm) consumes the shards natively.
//!
//! Backpressure is real: workers consume from bounded channels; a slow
//! worker stalls the generator (counted in [`PipelineStats`]).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};

use crate::graph::{ShardedGraph, Vertex};
use crate::mpc::simulator::machine_of;
use crate::util::dsu::DisjointSet;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub num_workers: usize,
    /// Edges per chunk sent over a channel.
    pub chunk_size: usize,
    /// Bounded channel capacity, in chunks (the backpressure knob).
    pub channel_capacity: usize,
    /// Residency budget for the summary graph, in bytes: over budget, the
    /// assembled summary is written out one shard file per worker and
    /// dropped from RAM, and every downstream generation inherits the
    /// budget.  (Assembly itself materializes the summary once — it is
    /// the workers' *spanning* edges, ~n per worker, not the input
    /// stream.)  `None` = resident.
    pub spill_budget: Option<u64>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            num_workers: std::thread::available_parallelism()
                .map(|n| n.get().clamp(2, 8))
                .unwrap_or(4),
            chunk_size: 64 * 1024,
            channel_capacity: 4,
            spill_budget: None,
        }
    }
}

/// Observability counters for a pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub edges_streamed: u64,
    pub chunks: u64,
    /// Times the generator found a worker queue full and had to wait.
    pub backpressure_stalls: u64,
    pub per_worker_edges: Vec<u64>,
    /// Summary-graph (spanning) edges emitted by all workers.
    pub summary_edges: u64,
    pub generate_ms: f64,
    pub merge_ms: f64,
}

/// Result: canonical labels plus stats.
pub struct PipelineResult {
    pub labels: Vec<Vertex>,
    pub stats: PipelineStats,
    /// The summary graph, resident in sharded form (one shard per worker),
    /// exposed so callers can run a paper algorithm on it (the end-to-end
    /// example feeds it to LocalContraction + XLA via
    /// `Driver::run_named_sharded`).
    pub summary: ShardedGraph,
}

/// Run the pipeline: stream `edges` over `n` vertices through shard-local
/// contraction, returning the sharded summary graph and per-worker stats.
///
/// The final global merge is left to the caller (see
/// [`merge_summary`] for the plain union-find finisher).
pub fn run<I>(n: usize, edges: I, cfg: &PipelineConfig) -> PipelineResult
where
    I: IntoIterator<Item = (Vertex, Vertex)>,
{
    let w = cfg.num_workers.max(1);
    let mut stats = PipelineStats {
        per_worker_edges: vec![0; w],
        ..Default::default()
    };

    // worker channels + threads
    let mut senders: Vec<SyncSender<Vec<(Vertex, Vertex)>>> = Vec::with_capacity(w);
    let mut handles = Vec::with_capacity(w);
    for _ in 0..w {
        let (tx, rx): (_, Receiver<Vec<(Vertex, Vertex)>>) =
            sync_channel(cfg.channel_capacity.max(1));
        senders.push(tx);
        handles.push(std::thread::spawn(move || {
            // Shard-local contraction: streaming union-find over the shard's
            // edges; emits one spanning edge per successful union.  Every
            // spanning edge is an input edge of this shard, so the output
            // satisfies the shard-ownership invariant by construction.
            let mut dsu = DisjointSet::new(n);
            let mut summary: Vec<(Vertex, Vertex)> = Vec::new();
            let mut edges_seen = 0u64;
            while let Ok(chunk) = rx.recv() {
                for (u, v) in chunk {
                    edges_seen += 1;
                    if dsu.union(u, v) {
                        summary.push((u, v));
                    }
                }
            }
            (summary, edges_seen)
        }));
    }

    // generator: route chunks by the shard-ownership hash, with backpressure
    let t0 = std::time::Instant::now();
    let mut buffers: Vec<Vec<(Vertex, Vertex)>> = vec![Vec::new(); w];
    let send_chunk = |wid: usize,
                          chunk: Vec<(Vertex, Vertex)>,
                          stalls: &mut u64| {
        let mut pending = chunk;
        loop {
            match senders[wid].try_send(pending) {
                Ok(()) => break,
                Err(TrySendError::Full(back)) => {
                    *stalls += 1;
                    pending = back;
                    std::thread::yield_now();
                    // blocking send after one counted stall
                    senders[wid].send(pending).expect("worker died");
                    break;
                }
                Err(TrySendError::Disconnected(_)) => panic!("worker died"),
            }
        }
    };
    for (u, v) in edges {
        let wid = machine_of(u.min(v) as u64, w);
        stats.edges_streamed += 1;
        stats.per_worker_edges[wid] += 1;
        buffers[wid].push((u, v));
        if buffers[wid].len() >= cfg.chunk_size {
            let chunk = std::mem::take(&mut buffers[wid]);
            stats.chunks += 1;
            send_chunk(wid, chunk, &mut stats.backpressure_stalls);
        }
    }
    for (wid, buf) in buffers.into_iter().enumerate() {
        if !buf.is_empty() {
            stats.chunks += 1;
            send_chunk(wid, buf, &mut stats.backpressure_stalls);
        }
    }
    drop(senders); // close channels
    stats.generate_ms = t0.elapsed().as_secs_f64() * 1e3;

    // collect: worker w's spanning edges are summary shard w — normalize
    // them shard-locally, never through one flat list
    let t1 = std::time::Instant::now();
    let mut buckets: Vec<Vec<(Vertex, Vertex)>> = Vec::with_capacity(w);
    for h in handles {
        let (summary, _edges_seen) = h.join().expect("worker panicked");
        buckets.push(summary);
    }
    let summary = ShardedGraph::from_shard_buckets_with(
        n,
        buckets,
        crate::graph::SpillPolicy::with_budget(cfg.spill_budget),
    );
    stats.summary_edges = summary.num_edges() as u64;
    stats.merge_ms = t1.elapsed().as_secs_f64() * 1e3;

    PipelineResult {
        labels: Vec::new(), // filled by the caller's merge step
        stats,
        summary,
    }
}

/// Plain global finisher: union-find straight over the summary shards.
pub fn merge_summary(summary: &ShardedGraph) -> Vec<Vertex> {
    crate::cc::oracle::components_sharded(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    fn cfg(workers: usize) -> PipelineConfig {
        PipelineConfig {
            num_workers: workers,
            chunk_size: 128,
            channel_capacity: 2,
            spill_budget: None,
        }
    }

    #[test]
    fn pipeline_matches_oracle() {
        let g = generators::gnp(2000, 0.002, &mut Rng::new(3));
        let res = run(2000, g.edges().iter().copied(), &cfg(4));
        let labels = merge_summary(&res.summary);
        assert_eq!(labels, crate::cc::oracle::components(&g));
        assert_eq!(res.stats.edges_streamed, g.num_edges() as u64);
    }

    #[test]
    fn summary_shards_are_worker_aligned() {
        let g = generators::gnp(500, 0.01, &mut Rng::new(8));
        let res = run(500, g.edges().iter().copied(), &cfg(3));
        assert_eq!(res.summary.num_shards(), 3);
        for s in 0..3 {
            for (u, v) in res.summary.read_shard(s).unwrap().iter() {
                assert_eq!(machine_of(u.min(v) as u64, 3), s);
            }
        }
    }

    #[test]
    fn spilled_summary_matches_resident() {
        let g = generators::gnp(800, 0.006, &mut Rng::new(12));
        let resident = run(800, g.edges().iter().copied(), &cfg(4));
        let spilled = run(
            800,
            g.edges().iter().copied(),
            &PipelineConfig {
                spill_budget: Some(0),
                ..cfg(4)
            },
        );
        assert!(spilled.summary.is_spilled());
        assert_eq!(spilled.summary, resident.summary);
        assert_eq!(
            merge_summary(&spilled.summary),
            crate::cc::oracle::components(&g)
        );
    }

    #[test]
    fn summary_is_much_smaller_than_input_on_dense_graph() {
        let g = generators::complete(300); // ~45k edges, 1 component
        let res = run(300, g.edges().iter().copied(), &cfg(4));
        // spanning edges per worker <= n-1 each
        assert!(res.stats.summary_edges < 4 * 300);
        assert!(res.stats.summary_edges >= 299);
        let labels = merge_summary(&res.summary);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn single_worker_works() {
        let g = generators::path(500);
        let res = run(500, g.edges().iter().copied(), &cfg(1));
        assert_eq!(merge_summary(&res.summary), crate::cc::oracle::components(&g));
    }

    #[test]
    fn stats_account_all_edges() {
        let g = generators::grid(30, 30);
        let res = run(900, g.edges().iter().copied(), &cfg(3));
        let per_worker: u64 = res.stats.per_worker_edges.iter().sum();
        assert_eq!(per_worker, g.num_edges() as u64);
        assert!(res.stats.chunks >= 1);
    }

    #[test]
    fn empty_stream() {
        let res = run(10, std::iter::empty(), &cfg(2));
        assert_eq!(res.stats.edges_streamed, 0);
        let labels = merge_summary(&res.summary);
        assert_eq!(labels, (0..10u32).collect::<Vec<_>>());
    }
}
