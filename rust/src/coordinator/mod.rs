//! Layer-3 coordinator: configuration, run driver, streaming shard
//! pipeline, and reports.
//!
//! This is the deployment surface of the system: the `lcc` binary's
//! subcommands are thin wrappers over [`Driver`] (single runs and table
//! sweeps) and [`pipeline`] (the streaming scale-out path).

pub mod driver;
pub mod pipeline;
pub mod report;
pub mod worker;

pub use driver::{Driver, DriverSession, RunConfig};
pub use pipeline::{PipelineConfig, PipelineResult, PipelineStats};
pub use report::Report;
