//! The run driver: configuration -> transport -> engine -> algorithm ->
//! report.
//!
//! The driver is where the round transport is selected
//! ([`RunConfig::transport`]): `inproc` builds the classic single-process
//! engine; `proc` spawns one `lcc worker` process per machine
//! ([`crate::mpc::net::ProcTransport`]), ships each its shard, and runs
//! the *same* algorithm code against the multi-process backend; `shuffle`
//! additionally brings up the worker↔worker mesh
//! ([`crate::mpc::net::ShuffleTransport`]) so the hop and rewire rounds
//! are generated on the workers and shuffled peer to peer — the
//! coordinator link carries descriptors and O(machines) summaries.
//! Transport faults (worker crash, truncated frame, corrupted payload,
//! accounting divergence) surface as typed
//! [`TransportError`]s from the `try_*` entry points — the panicking
//! entry points keep their historical signatures for in-process use.

use std::panic::AssertUnwindSafe;

use super::report::Report;
use crate::cc::{self, CcAlgorithm, RunOptions};
use crate::graph::{Graph, ShardedGraph};
use crate::mpc::net::{ProcTransport, ShuffleTransport};
use crate::mpc::{MpcConfig, Simulator, TransportError, TransportMode};
use crate::runtime::ShardExecutor;
use crate::util::rng::Rng;

/// Full configuration of one run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Algorithm CLI name (see [`cc::by_name`]).
    pub algorithm: String,
    pub seed: u64,
    pub machines: usize,
    /// Simulation threads (not a model parameter).
    pub threads: usize,
    /// §6 small-graph finisher threshold in edges (0 = off).
    pub finisher_threshold: usize,
    /// §6 isolated-node pruning.
    pub prune_isolated: bool,
    pub max_phases: u32,
    /// Hash-To-Min state guard (total stored ids; 0 = off).
    pub state_cap: u64,
    /// Use the compiled XLA dense backend when the graph fits a shard.
    pub use_xla: bool,
    /// Resident-memory budget for the sharded edge store, in bytes
    /// (`--spill-budget`): graphs whose edge set exceeds it run with
    /// disk-backed shards through the same contraction loop.  `None` =
    /// unbounded.
    pub spill_budget: Option<u64>,
    /// Round transport (`--transport`): `InProc` (default), `Proc` (one
    /// worker process per machine, coordinator-routed rounds), or
    /// `Shuffle` (worker processes plus a worker↔worker data plane).
    pub transport: TransportMode,
    /// Worker binary the proc transport spawns; `None` = this executable
    /// (the `lcc` binary spawns itself as `lcc worker`).  Tests point it
    /// at `env!("CARGO_BIN_EXE_lcc")`.
    pub worker_bin: Option<std::path::PathBuf>,
    /// Cross-check the labels against the sequential oracle.
    pub verify: bool,
    /// Socket I/O timeout in seconds (`--io-timeout`); `None` = the
    /// environment (`LCC_IO_TIMEOUT_MS`) or [`crate::mpc::net::IO_TIMEOUT`].
    pub io_timeout_secs: Option<u64>,
    /// Worker mesh connect attempt budget, exponential backoff
    /// (`--connect-retries`); `None` = environment or default.
    pub connect_retries: Option<usize>,
    /// Deterministic fault plan (`--fault-plan`,
    /// e.g. `"kill:w2@round=3,delay:w1@round=5"`), shipped to the
    /// spawned workers through their environment.
    pub fault_plan: Option<String>,
    /// Worker respawn attempts per recovery (`--respawn-budget`; 0
    /// disables recovery — a dead worker is then terminal).  `None` =
    /// environment or default.
    pub respawn_budget: Option<usize>,
    /// Persist per-generation run checkpoints into this directory
    /// (`--checkpoint-dir`); `None` = a run-private temp dir whenever
    /// recovery is enabled on the shuffle transport.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// How many checkpointed `gen-<id>/` custody directories to retain
    /// (`--keep-generations`); `None` = environment
    /// (`LCC_KEEP_GENERATIONS`) or the compiled-in default of 1.
    /// Long-lived sessions ([`Driver::into_session`]) recontract
    /// indefinitely, so retention is what bounds their checkpoint disk.
    pub keep_generations: Option<usize>,
    /// Data-plane threads per spawned worker (`--worker-threads`,
    /// shipped as `LCC_WORKER_THREADS`); `None` = environment or the
    /// serial default of 1.  Bit-identical outputs at every value —
    /// this is pure wall-clock parallelism inside the worker processes.
    pub worker_threads: Option<usize>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algorithm: "lc".into(),
            seed: 42,
            machines: 16,
            threads: std::thread::available_parallelism()
                .map(|n| n.get().min(16))
                .unwrap_or(4),
            finisher_threshold: 0,
            prune_isolated: true,
            max_phases: 200,
            state_cap: 0,
            use_xla: false,
            spill_budget: None,
            transport: TransportMode::InProc,
            worker_bin: None,
            verify: false,
            io_timeout_secs: None,
            connect_retries: None,
            fault_plan: None,
            respawn_budget: None,
            checkpoint_dir: None,
            keep_generations: None,
            worker_threads: None,
        }
    }
}

/// Owns the (optionally XLA-backed) execution environment for runs.
pub struct Driver {
    cfg: RunConfig,
    executor: Option<ShardExecutor>,
}

impl Driver {
    /// Build a driver; when `use_xla` is set, loads + compiles the
    /// artifacts once (they are reused across runs and phases).
    pub fn new(cfg: RunConfig) -> Driver {
        let executor = if cfg.use_xla {
            match crate::runtime::try_default_executor() {
                Ok(e) => {
                    eprintln!(
                        "[driver] XLA dense backend ready: platform={}, shard={}",
                        e.platform(),
                        e.shard_size()
                    );
                    Some(e)
                }
                Err(e) => {
                    eprintln!(
                        "[driver] WARNING: --use-xla requested but artifacts unavailable \
                         ({e}); falling back to the MPC path. Run `make artifacts`."
                    );
                    None
                }
            }
        } else {
            None
        };
        Driver { cfg, executor }
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn has_xla(&self) -> bool {
        self.executor.is_some()
    }

    /// Run the configured algorithm on `g`, returning the full report.
    pub fn run(&self, g: &Graph) -> Report {
        self.run_named(g, "graph")
    }

    /// Run with a dataset name recorded in the report.  Shards `g` once by
    /// `cfg.machines` (the ingest step) under the configured residency
    /// budget and runs on the resident (or disk-backed) store.
    pub fn run_named(&self, g: &Graph, dataset: &str) -> Report {
        self.try_run_named(g, dataset)
            .unwrap_or_else(|e| panic!("transport failed: {e}"))
    }

    /// [`run_named`](Self::run_named) surfacing transport faults as typed
    /// errors (the multi-process path; in-process runs cannot fail this
    /// way).
    pub fn try_run_named(&self, g: &Graph, dataset: &str) -> Result<Report, TransportError> {
        let sharded = ShardedGraph::from_graph_with(
            g,
            self.cfg.machines.max(1),
            self.spill_policy(),
        );
        self.try_run_sharded_seeded(&sharded, dataset, self.cfg.seed)
    }

    /// The residency policy every run of this driver shards under.
    fn spill_policy(&self) -> crate::graph::SpillPolicy {
        crate::graph::SpillPolicy::with_budget(self.cfg.spill_budget)
    }

    /// Run on an already-sharded graph (e.g. the pipeline's summary)
    /// without flattening.  A shard count differing from `cfg.machines`
    /// is re-partitioned shard-to-shard (`ShardedGraph::reshard`) — the
    /// edge list never round-trips through one flat vector.
    pub fn run_named_sharded(&self, g: &ShardedGraph, dataset: &str) -> Report {
        self.try_run_named_sharded(g, dataset)
            .unwrap_or_else(|e| panic!("transport failed: {e}"))
    }

    /// [`run_named_sharded`](Self::run_named_sharded) surfacing transport
    /// faults as typed errors (the pipeline's proc-transport merge path).
    pub fn try_run_named_sharded(
        &self,
        g: &ShardedGraph,
        dataset: &str,
    ) -> Result<Report, TransportError> {
        let machines = self.cfg.machines.max(1);
        let budgeted = self.cfg.spill_budget.is_some();
        if g.num_shards() != machines {
            // reshard first, then adopt the driver's budget on the
            // already-resharded generation — never spill a graph only to
            // stream it all back through a reshard
            let mut r = g.reshard(machines);
            if budgeted {
                r = r.with_policy(self.spill_policy());
            }
            self.try_run_sharded_seeded(&r, dataset, self.cfg.seed)
        } else if budgeted {
            // the run's generations must inherit the budget (and the
            // backend must match it), which lives on the graph: this is
            // the one path that needs an owned copy
            let g = g.clone().with_policy(self.spill_policy());
            self.try_run_sharded_seeded(&g, dataset, self.cfg.seed)
        } else {
            // default path: zero-copy
            self.try_run_sharded_seeded(g, dataset, self.cfg.seed)
        }
    }

    fn run_sharded_seeded(&self, g: &ShardedGraph, dataset: &str, seed: u64) -> Report {
        self.try_run_sharded_seeded(g, dataset, seed)
            .unwrap_or_else(|e| panic!("transport failed: {e}"))
    }

    /// Build the configured transport's engine for `g`.  The proc path
    /// spawns the workers and distributes the shards before the first
    /// round.
    fn build_simulator(&self, g: &ShardedGraph) -> Result<Simulator, TransportError> {
        let mpc = MpcConfig {
            machines: self.cfg.machines,
            space_per_machine: None,
            spill_budget: self.cfg.spill_budget,
            threads: self.cfg.threads,
        };
        let worker_bin = || -> Result<std::path::PathBuf, TransportError> {
            match &self.cfg.worker_bin {
                Some(p) => Ok(p.clone()),
                None => std::env::current_exe().map_err(|e| TransportError::Io {
                    worker: None,
                    op: "locate worker binary",
                    source: e,
                }),
            }
        };
        // CLI flags overlay the environment; the environment overlays the
        // compiled-in defaults (see NetConfig::from_env).
        let net_cfg = || {
            let mut c = crate::mpc::net::NetConfig::from_env();
            if let Some(secs) = self.cfg.io_timeout_secs {
                c.io_timeout = std::time::Duration::from_secs(secs);
            }
            if let Some(n) = self.cfg.connect_retries {
                c.connect_retries = n;
            }
            if self.cfg.fault_plan.is_some() {
                c.fault_plan = self.cfg.fault_plan.clone();
            }
            if let Some(n) = self.cfg.respawn_budget {
                c.respawn_budget = n;
            }
            if self.cfg.checkpoint_dir.is_some() {
                c.checkpoint_dir = self.cfg.checkpoint_dir.clone();
            }
            if let Some(k) = self.cfg.keep_generations {
                c.keep_generations = k.max(1);
            }
            if let Some(t) = self.cfg.worker_threads {
                c.worker_threads = t.max(1);
            }
            c
        };
        match self.cfg.transport {
            TransportMode::InProc => Ok(Simulator::new(mpc)),
            TransportMode::Proc => {
                let mut transport = ProcTransport::spawn_with(
                    self.cfg.machines.max(1),
                    &worker_bin()?,
                    net_cfg(),
                )?;
                transport.load_graph(g)?;
                Ok(Simulator::with_transport(mpc, Box::new(transport)))
            }
            TransportMode::Shuffle => {
                let cfg = net_cfg();
                let recovery_on = cfg.respawn_budget > 0;
                let checkpoint_root = cfg.checkpoint_dir.clone();
                let mut transport = ShuffleTransport::spawn_with(
                    self.cfg.machines.max(1),
                    &worker_bin()?,
                    cfg,
                )?;
                if recovery_on {
                    // Recovery re-ships custody from the checkpointed spill
                    // files, so checkpointing is on whenever respawn is.
                    let dir = match checkpoint_root {
                        Some(d) => {
                            std::fs::create_dir_all(&d).map_err(|e| TransportError::Io {
                                worker: None,
                                op: "create checkpoint dir",
                                source: e,
                            })?;
                            crate::graph::spill::SpillDir::adopt(d)
                        }
                        None => crate::graph::spill::SpillDir::create_temp(None)?,
                    };
                    transport.set_checkpoint(dir, Rng::new(self.cfg.seed).state());
                }
                transport.load_graph(g)?;
                Ok(Simulator::with_transport(mpc, Box::new(transport)))
            }
        }
    }

    fn try_run_sharded_seeded(
        &self,
        g: &ShardedGraph,
        dataset: &str,
        seed: u64,
    ) -> Result<Report, TransportError> {
        let mut sim = self.build_simulator(g)?;
        self.run_in(&mut sim, g, dataset, seed).map(|(_, report)| report)
    }

    /// One run of the configured algorithm on an already-built engine —
    /// the body every entry point (and every [`DriverSession`] run)
    /// shares.  Returns the labels alongside the report: batch callers
    /// drop them, the incremental service (`lcc serve`) publishes them as
    /// its next snapshot.
    fn run_in(
        &self,
        sim: &mut Simulator,
        g: &ShardedGraph,
        dataset: &str,
        seed: u64,
    ) -> Result<(Vec<u32>, Report), TransportError> {
        let algo = cc::by_name(&self.cfg.algorithm);
        let mut rng = Rng::new(seed);
        let xla_before = self.executor.as_ref().map(|e| e.calls.get()).unwrap_or(0);
        let opts = RunOptions {
            finisher_threshold: self.cfg.finisher_threshold,
            prune_isolated: self.cfg.prune_isolated,
            max_phases: self.cfg.max_phases,
            state_cap: self.cfg.state_cap,
            dense_backend: self
                .executor
                .as_ref()
                .map(|e| e as &dyn cc::backend::DenseBackend),
        };
        let t0 = std::time::Instant::now();
        // A transport failure aborts the algorithm by unwinding with the
        // typed error as payload (see mpc::transport docs): catch it here
        // and hand it back as a Result; any other panic is re-raised.
        let res = match std::panic::catch_unwind(AssertUnwindSafe(|| {
            algo.run_sharded(g, sim, &mut rng, &opts)
        })) {
            Ok(res) => res,
            Err(payload) => match payload.downcast::<TransportError>() {
                Ok(e) => return Err(*e),
                Err(other) => std::panic::resume_unwind(other),
            },
        };
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut report = Report::from_result(
            algo.name(),
            dataset,
            g.num_vertices(),
            g.num_edges(),
            &res,
            wall_ms,
        );
        report.transport = self.cfg.transport.name().to_string();
        report.mesh = sim.mesh_metrics();
        report.xla_calls =
            self.executor.as_ref().map(|e| e.calls.get()).unwrap_or(0) - xla_before;
        if self.cfg.verify {
            report.verified = Some(res.labels == cc::oracle::components_sharded(g));
        }
        Ok((res.labels, report))
    }

    /// Bring up the configured transport once and keep it: the returned
    /// session owns the driver and the live engine, and every
    /// [`DriverSession::run`] reuses the fleet (persistent workers, warm
    /// sockets, checkpoint state) instead of spawning and tearing it down
    /// per run.  This is the `lcc serve` lifecycle; batch entry points
    /// are unchanged.  `g` is the first resident graph — it is shipped to
    /// the workers here, so the first `run` on the same graph pays no
    /// second custody load.
    pub fn into_session(self, g: &ShardedGraph) -> Result<DriverSession, TransportError> {
        let sim = self.build_simulator(g)?;
        Ok(DriverSession {
            driver: self,
            sim,
            runs: 0,
        })
    }

    /// Median-of-`k`-seeds wall time protocol (§6: "we have taken a median
    /// from three runs").  Shards once, runs `k` times, returns the
    /// median-wall-time report.
    pub fn run_median(&self, g: &Graph, dataset: &str, k: usize) -> Report {
        assert!(k >= 1);
        let sharded = ShardedGraph::from_graph_with(
            g,
            self.cfg.machines.max(1),
            self.spill_policy(),
        );
        let mut reports: Vec<Report> = (0..k)
            .map(|i| {
                self.run_sharded_seeded(
                    &sharded,
                    dataset,
                    self.cfg.seed.wrapping_add(i as u64 * 1000),
                )
            })
            .collect();
        reports.sort_by(|a, b| a.wall_ms.partial_cmp(&b.wall_ms).unwrap());
        reports.swap_remove(k / 2)
    }
}

/// A persistent run session ([`Driver::into_session`]): the transport is
/// brought up once and every run reuses it.  On the wire transports the
/// worker fleet, its sockets, and its checkpoint state survive between
/// runs — the daemon lifecycle `lcc serve` is built on; in-process, the
/// session simply keeps the engine's scratch warm.  Dropping the session
/// drops the engine, which tears the fleet down.
pub struct DriverSession {
    driver: Driver,
    sim: Simulator,
    /// Completed runs; run 0's graph was already shipped by
    /// [`Driver::into_session`], every later run re-establishes custody
    /// (the workers hold the *contracted* generation after a run, never
    /// the input one).
    runs: u64,
}

impl DriverSession {
    /// The configuration every run of this session executes under.
    pub fn config(&self) -> &RunConfig {
        &self.driver.cfg
    }

    /// Transport backend name (`"inproc"` / `"proc"` / `"shuffle"`).
    pub fn transport_name(&self) -> &'static str {
        self.sim.transport_name()
    }

    /// Run the configured algorithm on `g` over the live fleet,
    /// returning the canonical labels (min vertex id per component —
    /// what the incremental service publishes as a snapshot) alongside
    /// the usual report.  `g` must be sharded to the session's machine
    /// count.  Runs are seeded like [`Driver::run_median`]'s protocol
    /// (base seed + 1000 per run) so successive recontractions draw
    /// independent priority streams; labels are canonical, hence
    /// seed-independent.
    pub fn run(
        &mut self,
        g: &ShardedGraph,
        dataset: &str,
    ) -> Result<(Vec<u32>, Report), TransportError> {
        if self.runs > 0 {
            self.sim.begin_run(g)?;
        }
        let seed = self.driver.cfg.seed.wrapping_add(self.runs * 1000);
        self.runs += 1;
        self.driver.run_in(&mut self.sim, g, dataset, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn driver_runs_and_reports() {
        let g = generators::gnp(300, 0.01, &mut Rng::new(7));
        let cfg = RunConfig {
            verify: true,
            ..Default::default()
        };
        let report = Driver::new(cfg).run_named(&g, "gnp300");
        assert!(report.completed);
        assert_eq!(report.verified, Some(true));
        assert_eq!(report.n, 300);
        assert!(report.rounds >= report.phases as usize);
    }

    #[test]
    fn driver_all_algorithms_agree() {
        let g = generators::gnp(150, 0.02, &mut Rng::new(8));
        let want = crate::cc::oracle::components(&g);
        for name in crate::cc::ALL_ALGORITHMS {
            let cfg = RunConfig {
                algorithm: name.to_string(),
                ..Default::default()
            };
            let d = Driver::new(cfg);
            let algo = cc::by_name(name);
            let mut sim = Simulator::new(MpcConfig::default());
            let mut rng = Rng::new(1);
            let res = algo.run(&g, &mut sim, &mut rng, &RunOptions::default());
            assert_eq!(res.labels, want, "{name}");
            drop(d);
        }
    }

    #[test]
    fn median_of_three() {
        let g = generators::path(100);
        let d = Driver::new(RunConfig::default());
        let r = d.run_median(&g, "path", 3);
        assert!(r.completed);
        assert_eq!(r.num_components, 1);
    }

    #[test]
    fn finisher_reduces_phases() {
        let g = generators::path(2000);
        let mut cfg = RunConfig::default();
        let baseline = Driver::new(cfg.clone()).run(&g);
        cfg.finisher_threshold = 500;
        let with_fin = Driver::new(cfg).run(&g);
        assert!(with_fin.phases <= baseline.phases);
        assert!(with_fin.completed);
    }
}
