//! Graph substrate: storage, generators, IO, statistics.
//!
//! Two representations live here:
//!
//! * [`edgelist::Graph`] — the flat **ingest/oracle format**: dense `u32`
//!   vertex ids plus one canonical undirected edge list.  Generators, IO,
//!   statistics, the sequential oracle, and the dense XLA backend speak
//!   this.
//! * [`sharded::ShardedGraph`] — the **resident representation** everything
//!   above the ingest boundary computes on.  Edges are partitioned into
//!   one [`spill::EdgeShard`] per simulated machine under the invariant
//!   *the canonical edge `(u, v)`, `u < v`, lives on machine
//!   `machine_of(u, machines)`* — the same stable hash the MPC shuffle
//!   rounds key by, with `MpcConfig::machines` the single source of the
//!   shard count.  Normalize, contract, and prune run shard-parallel and
//!   re-bucket rewritten edges into their new owner shards in the same
//!   pass; cached per-shard ownership histograms make every round's
//!   per-machine byte load a **pure function of shard membership** (see
//!   [`sharded`] module docs and `crate::mpc`).
//! * [`spill`] — **out-of-core residency** for the shards: a
//!   [`spill::ShardStore`] backend per graph, either fully in RAM
//!   ([`spill::Resident`]) or one checksummed file per shard
//!   ([`spill::Spilled`]) once the edge set exceeds the graph's
//!   [`spill::SpillPolicy`] budget.  Only the cached histograms stay
//!   resident; mutations run load → rewrite → spill shard by shard, so
//!   graphs larger than RAM flow through the same contraction loop.
//!
//! Conversions ([`sharded::ShardedGraph::from_graph`] /
//! [`sharded::ShardedGraph::to_graph`]) are bit-exact round trips; the
//! cross-representation tests in `rust/tests/sharded_representation.rs`
//! and `rust/tests/spill_equivalence.rs` enforce that every sharded
//! operation matches its monolithic counterpart on **both** backends.

pub mod csr;
pub mod edgelist;
pub mod generators;
pub mod io;
pub mod sharded;
pub mod spill;
pub mod stats;

pub use csr::Csr;
pub use edgelist::{compact_labels, label_ranks, Graph, Vertex};
pub use sharded::ShardedGraph;
pub use spill::{EdgeShard, ShardStore, SpillError, SpillPolicy};
