//! Graph substrate: storage, generators, IO, statistics.
//!
//! Everything above this layer (MPC simulator, algorithms, coordinator)
//! speaks [`edgelist::Graph`] — dense `u32` vertex ids plus a canonical
//! undirected edge list.

pub mod csr;
pub mod edgelist;
pub mod generators;
pub mod io;
pub mod stats;

pub use csr::Csr;
pub use edgelist::{label_ranks, Graph, Vertex};
