//! Graph statistics: component structure, degree profile, diameter estimate.
//!
//! Backs the `lcc table1` harness (regenerating the dataset-inventory table)
//! and the structural assertions in the preset tests.

use super::csr::Csr;
use super::edgelist::Graph;
use crate::util::dsu::DisjointSet;
use crate::util::stats::Log2Histogram;

/// Connected-component structure computed by the sequential oracle.
#[derive(Debug, Clone)]
pub struct ComponentStats {
    pub count: usize,
    pub largest: usize,
    /// log2 histogram of component sizes
    pub size_hist: Log2Histogram,
}

pub fn component_stats(g: &Graph) -> ComponentStats {
    let mut d = DisjointSet::new(g.num_vertices());
    for &(u, v) in g.edges() {
        d.union(u, v);
    }
    let mut size_hist = Log2Histogram::new();
    let mut largest = 0usize;
    let mut seen = std::collections::HashMap::new();
    for v in 0..g.num_vertices() as u32 {
        let r = d.find(v);
        *seen.entry(r).or_insert(0usize) += 1;
    }
    for &s in seen.values() {
        size_hist.add(s as u64);
        largest = largest.max(s);
    }
    ComponentStats {
        count: d.components(),
        largest,
        size_hist,
    }
}

/// Degree profile.
#[derive(Debug, Clone)]
pub struct DegreeStats {
    pub avg: f64,
    pub max: u32,
    pub hist: Log2Histogram,
}

pub fn degree_stats(g: &Graph) -> DegreeStats {
    let deg = g.degrees();
    let mut hist = Log2Histogram::new();
    let mut max = 0u32;
    let mut sum = 0u64;
    for &d in &deg {
        hist.add(d as u64);
        max = max.max(d);
        sum += d as u64;
    }
    DegreeStats {
        avg: if deg.is_empty() { 0.0 } else { sum as f64 / deg.len() as f64 },
        max,
        hist,
    }
}

/// Double-sweep BFS lower bound on the diameter of the component of `src`
/// (exact on trees, a good estimate elsewhere).  The paper's motivation in
/// §1 — real graphs have `d ≈ log n` — is checked with this.
pub fn diameter_estimate(g: &Graph) -> u32 {
    if g.num_edges() == 0 {
        return 0;
    }
    let csr = Csr::build(g);
    // start from an endpoint of the first edge (inside some component)
    let src = g.edges()[0].0;
    let (_, far) = csr.bfs(src);
    let (dist, far2) = csr.bfs(far);
    dist[far2 as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    #[test]
    fn component_stats_on_mixture() {
        let g = generators::path(10)
            .disjoint_union(generators::complete(5))
            .disjoint_union(Graph::empty(3));
        let s = component_stats(&g);
        assert_eq!(s.count, 2 + 3); // path, clique, 3 isolated
        assert_eq!(s.largest, 10);
    }

    #[test]
    fn degree_stats_on_star() {
        let s = degree_stats(&generators::star(11));
        assert_eq!(s.max, 10);
        assert!((s.avg - 20.0 / 11.0).abs() < 1e-9);
    }

    #[test]
    fn diameter_of_path_is_exact() {
        assert_eq!(diameter_estimate(&generators::path(100)), 99);
    }

    #[test]
    fn diameter_of_clique_is_one() {
        assert_eq!(diameter_estimate(&generators::complete(10)), 1);
    }

    #[test]
    fn diameter_of_random_graph_is_logarithmic() {
        let mut rng = Rng::new(1);
        let g = generators::gnp_log_regime(4000, 3.0, &mut rng);
        let d = diameter_estimate(&g);
        // log2(4000) ~ 12; the paper's d ≈ log n observation
        assert!(d >= 3 && d <= 24, "diameter {d}");
    }

    #[test]
    fn empty_graph_diameter_zero() {
        assert_eq!(diameter_estimate(&Graph::empty(5)), 0);
    }
}
