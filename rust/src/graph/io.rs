//! Graph IO: SNAP-style text edge lists and a compact binary format.
//!
//! Text format matches the SNAP collection the paper's public datasets come
//! from: one `u<TAB-or-space>v` pair per line, `#` comments.  The binary
//! format is a little-endian `(magic, n, m, pairs...)` layout for fast
//! re-loading of generated benchmark inputs.
//!
//! The low-level pair framing ([`PAIR_BYTES`], [`write_pairs`],
//! [`read_pairs`]) is shared with the out-of-core shard files of
//! [`super::spill`]; both formats validate on-disk counts against the
//! actual file length **before** pre-allocating.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::edgelist::{Graph, Vertex};

const MAGIC: &[u8; 8] = b"LCCGRAPH";

/// Encoded size of one `(u32, u32)` edge pair.
pub const PAIR_BYTES: u64 = 8;

/// Write edge pairs little-endian (the payload encoding shared by the
/// graph container format and the spill shard framing).
pub fn write_pairs<W: Write>(w: &mut W, edges: &[(Vertex, Vertex)]) -> std::io::Result<()> {
    for &(u, v) in edges {
        w.write_all(&u.to_le_bytes())?;
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Cap on the *eager* reservation a declared edge count may drive before
/// any payload byte has been seen: 1 Mi pairs (8 MiB).  Larger vectors
/// grow amortized as real data actually arrives, so a validated caller
/// pays at most one extra copy while a lying header read through an
/// unvalidated path cannot reserve unbounded memory up front.
const READ_PAIRS_RESERVE_CAP: usize = 1 << 20;

/// Read exactly `m` edge pairs.  Callers are expected to validate `m`
/// against the source length first (see [`read_binary`] and the spill
/// framing in [`super::spill`]); defensively, the pre-allocation is
/// clamped to [`READ_PAIRS_RESERVE_CAP`] regardless, so a declared count
/// can never reserve more than the payload bytes actually delivered plus
/// one bounded chunk.
pub fn read_pairs<R: Read>(r: &mut R, m: usize) -> std::io::Result<Vec<(Vertex, Vertex)>> {
    let mut edges = Vec::with_capacity(m.min(READ_PAIRS_RESERVE_CAP));
    let mut pair = [0u8; 8];
    for _ in 0..m {
        r.read_exact(&mut pair)?;
        edges.push((
            u32::from_le_bytes(pair[0..4].try_into().unwrap()),
            u32::from_le_bytes(pair[4..8].try_into().unwrap()),
        ));
    }
    Ok(edges)
}

/// Decode an in-memory little-endian pair payload (the inverse of
/// [`write_pairs`]).  `bytes.len()` must be a multiple of [`PAIR_BYTES`]
/// — callers validate lengths before decoding (the spill framing and the
/// transport frames both do).
pub fn decode_pairs(bytes: &[u8]) -> Vec<(Vertex, Vertex)> {
    debug_assert_eq!(bytes.len() % PAIR_BYTES as usize, 0);
    bytes
        .chunks_exact(PAIR_BYTES as usize)
        .map(|c| {
            (
                u32::from_le_bytes(c[0..4].try_into().unwrap()),
                u32::from_le_bytes(c[4..8].try_into().unwrap()),
            )
        })
        .collect()
}

/// Read a SNAP-style text edge list.  Vertex ids may be sparse; they are
/// remapped to dense `0..n` in first-seen order.
pub fn read_snap_text<P: AsRef<Path>>(path: P) -> Result<Graph> {
    let f = File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    parse_snap_text(BufReader::new(f))
}

/// Parse SNAP text from any reader (exposed for tests).
pub fn parse_snap_text<R: BufRead>(reader: R) -> Result<Graph> {
    let mut remap = std::collections::HashMap::new();
    let mut next: Vertex = 0;
    let mut edges = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("line {}: expected two ids, got {:?}", lineno + 1, t),
        };
        let mut id = |raw: &str| -> Result<Vertex> {
            let k: u64 = raw
                .parse()
                .with_context(|| format!("line {}: bad id {raw:?}", lineno + 1))?;
            Ok(*remap.entry(k).or_insert_with(|| {
                let v = next;
                next += 1;
                v
            }))
        };
        let (u, v) = (id(a)?, id(b)?);
        edges.push((u, v));
    }
    Ok(Graph::from_edges(next as usize, edges))
}

/// Write as SNAP text.
pub fn write_snap_text<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    let f = File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# lcc graph: {} nodes {} edges", g.num_vertices(), g.num_edges())?;
    for &(u, v) in g.edges() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Write the compact binary format.
pub fn write_binary<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    let f = File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    write_pairs(&mut w, g.edges())?;
    Ok(())
}

/// Read the compact binary format.
///
/// The on-disk edge count is **not trusted**: it is validated against the
/// actual file length before any allocation, so a truncated, padded, or
/// corrupt header fails with a clear error instead of a bad pre-allocation
/// or a short read deep in the payload.
pub fn read_binary<P: AsRef<Path>>(path: P) -> Result<Graph> {
    let path = path.as_ref();
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("read magic")?;
    if &magic != MAGIC {
        bail!("not an lcc binary graph (bad magic)");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf);
    let expected = m
        .checked_mul(PAIR_BYTES)
        .and_then(|payload| payload.checked_add(24)); // magic + n + m
    match expected {
        Some(expected) if expected == file_len => {}
        _ => bail!(
            "{}: header claims {m} edges (file would be {} bytes) but the \
             file is {file_len} bytes — truncated or corrupt",
            path.display(),
            expected.map_or_else(|| "overflowing".to_string(), |e| e.to_string()),
        ),
    }
    let edges = read_pairs(&mut r, m as usize)?;
    Ok(Graph::from_edges_unchecked(n, edges))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::util::rng::Rng;

    #[test]
    fn snap_text_parse_basics() {
        let text = "# comment\n1 2\n2\t3\n\n10 1\n";
        let g = parse_snap_text(std::io::Cursor::new(text)).unwrap();
        // ids remapped first-seen: 1->0, 2->1, 3->2, 10->3
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.edges(), &[(0, 1), (0, 3), (1, 2)]);
    }

    #[test]
    fn snap_text_rejects_garbage() {
        assert!(parse_snap_text(std::io::Cursor::new("1\n")).is_err());
        assert!(parse_snap_text(std::io::Cursor::new("a b\n")).is_err());
    }

    #[test]
    fn text_roundtrip() {
        let mut rng = Rng::new(1);
        let g = generators::gnp(200, 0.05, &mut rng);
        let dir = std::env::temp_dir().join("lcc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.txt");
        write_snap_text(&g, &p).unwrap();
        let h = read_snap_text(&p).unwrap();
        // remapping is first-seen over canonical sorted edges = identity here
        assert_eq!(g.num_edges(), h.num_edges());
    }

    #[test]
    fn binary_roundtrip_exact() {
        let mut rng = Rng::new(2);
        let g = generators::chung_lu(300, 8.0, 2.5, &mut rng);
        let dir = std::env::temp_dir().join("lcc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        write_binary(&g, &p).unwrap();
        let h = read_binary(&p).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn binary_rejects_length_mismatch() {
        let mut rng = Rng::new(3);
        let g = generators::gnp(100, 0.05, &mut rng);
        let dir = std::env::temp_dir().join("lcc_io_test");
        std::fs::create_dir_all(&dir).unwrap();

        // truncated payload: drop the last 5 bytes
        let p = dir.join("trunc.bin");
        write_binary(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = read_binary(&p).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "{err}");

        // inflated header count over an intact payload
        let p2 = dir.join("badcount.bin");
        let mut bytes = std::fs::read({
            write_binary(&g, &p2).unwrap();
            &p2
        })
        .unwrap();
        let lie = (g.num_edges() as u64 + 1).to_le_bytes();
        bytes[16..24].copy_from_slice(&lie);
        std::fs::write(&p2, &bytes).unwrap();
        let err = read_binary(&p2).unwrap_err().to_string();
        assert!(err.contains("truncated or corrupt"), "{err}");
    }

    #[test]
    fn lying_edge_count_cannot_reserve_unbounded_memory() {
        // A declared count in the exabyte range must fail with a clean
        // read error, not drive `Vec::with_capacity` to an allocator
        // abort.  Reaching the `Err` at all is the regression check: an
        // unclamped reservation for this count would be ~100 PiB.
        let mut short: &[u8] = &[1, 0, 0, 0, 2, 0, 0, 0];
        let err = read_pairs(&mut short, usize::MAX / 16).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("lcc_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTAGRPH00000000").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
