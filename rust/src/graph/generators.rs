//! Graph generators: the workload side of the benchmark harness.
//!
//! Three families:
//!  * **structured** graphs for the theory experiments (§4, §7): paths,
//!    cycles, stars, grids, trees, cliques — including the two-cycles
//!    instance of the [YV17] hardness conjecture;
//!  * **random** models: `G(n,p)` (Gilbert) via skip sampling, the paper's
//!    superset class `𝒢(n,p)` (Definition 5.1), Chung–Lu, preferential
//!    attachment, and R-MAT;
//!  * **dataset presets** mirroring Table 1 at configurable scale (see
//!    [`presets`] and DESIGN.md §2 for the substitution argument).

use super::edgelist::{Graph, Vertex};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Structured graphs
// ---------------------------------------------------------------------------

/// Path `0-1-...-(n-1)` — the Ω(log n) lower-bound instance (Thm 7.1/7.2).
pub fn path(n: usize) -> Graph {
    Graph::from_edges(n, (1..n as Vertex).map(|v| (v - 1, v)).collect())
}

/// Cycle on `n` vertices.
pub fn cycle(n: usize) -> Graph {
    assert!(n >= 3, "cycle needs n >= 3");
    let mut edges: Vec<(Vertex, Vertex)> = (1..n as Vertex).map(|v| (v - 1, v)).collect();
    edges.push((0, n as Vertex - 1));
    Graph::from_edges(n, edges)
}

/// One cycle of length `2n` vs two cycles of length `n`: the instance the
/// [YV17] conjecture says needs Ω(log n) rounds to distinguish.
pub fn one_or_two_cycles(n: usize, two: bool) -> Graph {
    if two {
        cycle(n).disjoint_union(cycle(n))
    } else {
        cycle(2 * n)
    }
}

/// Star with center 0 — the CREW-PRAM worst case discussed in §1.2.
pub fn star(n: usize) -> Graph {
    Graph::from_edges(n, (1..n as Vertex).map(|v| (0, v)).collect())
}

/// Complete graph `K_n`.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n as Vertex {
        for v in (u + 1)..n as Vertex {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, edges)
}

/// `w x h` grid (diameter `w+h-2`, the moderate-diameter regime).
pub fn grid(w: usize, h: usize) -> Graph {
    let id = |x: usize, y: usize| (y * w + x) as Vertex;
    let mut edges = Vec::new();
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                edges.push((id(x, y), id(x + 1, y)));
            }
            if y + 1 < h {
                edges.push((id(x, y), id(x, y + 1)));
            }
        }
    }
    Graph::from_edges(w * h, edges)
}

/// Complete binary tree on `n` vertices (vertex 0 is the root).
pub fn binary_tree(n: usize) -> Graph {
    let edges = (1..n as Vertex).map(|v| ((v - 1) / 2, v)).collect();
    Graph::from_edges(n, edges)
}

/// Caterpillar: a spine path of length `spine` with `legs` leaves per
/// spine vertex.  Mixes the path lower bound with star-like fan-out.
pub fn caterpillar(spine: usize, legs: usize) -> Graph {
    let n = spine * (1 + legs);
    let mut edges = Vec::new();
    for s in 1..spine {
        edges.push(((s - 1) as Vertex, s as Vertex));
    }
    for s in 0..spine {
        for l in 0..legs {
            edges.push((s as Vertex, (spine + s * legs + l) as Vertex));
        }
    }
    Graph::from_edges(n, edges)
}

// ---------------------------------------------------------------------------
// Random models
// ---------------------------------------------------------------------------

/// Gilbert `G(n,p)` by geometric skip sampling: `O(n + m)` expected time.
pub fn gnp(n: usize, p: f64, rng: &mut Rng) -> Graph {
    assert!((0.0..=1.0).contains(&p), "p={p}");
    let mut edges = Vec::new();
    if p > 0.0 {
        for u in 0..n.saturating_sub(1) {
            let mut v = u as u64 + 1 + rng.skip_geometric(p);
            while (v as usize) < n {
                edges.push((u as Vertex, v as Vertex));
                v += 1 + rng.skip_geometric(p);
            }
        }
    }
    Graph::from_edges_unchecked(n, edges)
}

/// The paper's `𝒢(n,p)` class (Definition 5.1): a `G(n,p)` sample with an
/// arbitrary *fixed* edge set overlaid — every edge is at least as likely as
/// under `G(n,p)`.  Used to test that Theorem 5.5 survives adversarial
/// extra edges.
pub fn gnp_class(n: usize, p: f64, extra: &[(Vertex, Vertex)], rng: &mut Rng) -> Graph {
    let mut g = gnp(n, p, rng);
    for &(u, v) in extra {
        g.add_edge(u, v);
    }
    g.normalize();
    g
}

/// `G(n, c·ln n / n)` — the regime of §5 (connected w.h.p. for c > 1,
/// diameter ~ log n / log log n).
pub fn gnp_log_regime(n: usize, c: f64, rng: &mut Rng) -> Graph {
    let p = (c * (n as f64).ln() / n as f64).min(1.0);
    gnp(n, p, rng)
}

/// Chung–Lu: `m` endpoint-sampled edges with weights `w_v ∝ (v+1)^(-1/(β-1))`
/// (expected power-law degree exponent `β`).  May leave isolated vertices
/// and parallel edges (deduped); components are not guaranteed connected.
pub fn chung_lu(n: usize, avg_deg: f64, beta: f64, rng: &mut Rng) -> Graph {
    assert!(beta > 2.0, "beta must be > 2 for a finite mean");
    let gamma = 1.0 / (beta - 1.0);
    // cumulative weights for inverse-CDF endpoint sampling
    let mut cum = Vec::with_capacity(n);
    let mut total = 0.0f64;
    for v in 0..n {
        total += ((v + 1) as f64).powf(-gamma);
        cum.push(total);
    }
    let m = ((n as f64) * avg_deg / 2.0).round() as usize;
    let sample = |rng: &mut Rng| -> Vertex {
        let x = rng.next_f64() * total;
        cum.partition_point(|&c| c < x).min(n - 1) as Vertex
    };
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (u, v) = (sample(rng), sample(rng));
        if u != v {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, edges)
}

/// Preferential attachment (Barabási–Albert flavor): each new vertex
/// attaches to `m_per_vertex` earlier vertices chosen proportionally to
/// degree (via the repeated-endpoint trick).  Connected by construction,
/// power-law degrees — the "giant social component" building block.
pub fn preferential_attachment(n: usize, m_per_vertex: usize, rng: &mut Rng) -> Graph {
    assert!(m_per_vertex >= 1);
    let m = m_per_vertex;
    let mut targets: Vec<Vertex> = Vec::with_capacity(2 * n * m);
    let mut edges = Vec::with_capacity(n * m);
    let mut picked: Vec<Vertex> = Vec::with_capacity(m);
    for v in 1..n {
        picked.clear();
        for _ in 0..m.min(v) {
            // Choose uniformly from the endpoint multiset = degree-biased,
            // mixing in a uniform choice to keep the tail from exploding.
            let mut draw = |rng: &mut Rng| -> Vertex {
                if targets.is_empty() || rng.gen_bool(0.5) && v > 1 {
                    rng.gen_range(v as u64) as Vertex
                } else {
                    targets[rng.gen_range(targets.len() as u64) as usize]
                }
            };
            // Rejection-sample away duplicate targets for the same source:
            // repeats would collapse under normalize() and starve the
            // realized edge count below sum_v min(m, v).  Retries are
            // bounded so generation stays O(n*m) even on hub-heavy draws.
            let mut t = draw(rng);
            let mut tries = 0;
            while (t as usize >= v || picked.contains(&t)) && tries < 32 {
                t = draw(rng);
                tries += 1;
            }
            if t as usize >= v || picked.contains(&t) {
                // Deterministic fallback: the smallest id not yet attached
                // this batch (exists because picked.len() < m.min(v) <= v).
                t = (0..v as Vertex).find(|c| !picked.contains(c)).unwrap();
            }
            picked.push(t);
            edges.push((v as Vertex, t));
            targets.push(t);
            targets.push(v as Vertex);
        }
    }
    Graph::from_edges(n, edges)
}

/// R-MAT recursive quadrant sampler (webgraph analogue).  `scale` is
/// `log2(n)`; emits `m` (possibly duplicate) edges, deduped on build.
pub fn rmat(scale: u32, m: usize, probs: (f64, f64, f64, f64), rng: &mut Rng) -> Graph {
    let (a, b, c, d) = probs;
    assert!((a + b + c + d - 1.0).abs() < 1e-9, "R-MAT probs must sum to 1");
    let n = 1usize << scale;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let x = rng.next_f64();
            let (du, dv) = if x < a {
                (0, 0)
            } else if x < a + b {
                (0, 1)
            } else if x < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u != v {
            edges.push((u as Vertex, v as Vertex));
        }
    }
    Graph::from_edges(n, edges)
}

/// A guaranteed-connected component with roughly `avg_deg` average degree:
/// random-attachment spanning tree + Chung–Lu style extra edges.
pub fn connected_component(n: usize, avg_deg: f64, rng: &mut Rng) -> Graph {
    if n == 1 {
        return Graph::empty(1);
    }
    let mut edges: Vec<(Vertex, Vertex)> = Vec::new();
    for v in 1..n as Vertex {
        edges.push((v, rng.gen_range(v as u64) as Vertex));
    }
    let extra = (((avg_deg / 2.0 - 1.0).max(0.0)) * n as f64) as usize;
    for _ in 0..extra {
        let u = rng.gen_range(n as u64) as Vertex;
        let v = rng.gen_range(n as u64) as Vertex;
        if u != v {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, edges)
}

// ---------------------------------------------------------------------------
// Dataset presets (Table 1 analogues)
// ---------------------------------------------------------------------------

pub mod presets {
    //! Scaled synthetic analogues of the paper's Table 1 datasets.
    //!
    //! Each preset preserves the *structural* properties that drive phase
    //! counts — average degree `m/n`, heavy-tailed degree distribution, and
    //! the largest-CC fraction — while scaling `n` down to laptop size
    //! (the substitution table in DESIGN.md §2).

    use super::*;

    /// Paper-reported shape of a Table 1 dataset plus our generator.
    pub struct DatasetSpec {
        pub name: &'static str,
        /// Paper values (for EXPERIMENTS.md reporting).
        pub paper_nodes: f64,
        pub paper_edges: f64,
        pub paper_largest_cc: f64,
        /// Structural targets for the analogue.
        pub avg_deg: f64,
        pub largest_cc_frac: f64,
        /// Default analogue size (`lcc --scale` overrides).
        pub default_n: usize,
    }

    pub const ALL: [&str; 5] = ["orkut", "friendster", "clueweb", "videos", "webpages"];

    pub fn spec(name: &str) -> DatasetSpec {
        match name {
            "orkut" => DatasetSpec {
                name: "orkut",
                paper_nodes: 3e6,
                paper_edges: 117e6,
                paper_largest_cc: 3e6,
                avg_deg: 39.0,
                largest_cc_frac: 1.0,
                default_n: 50_000,
            },
            "friendster" => DatasetSpec {
                name: "friendster",
                paper_nodes: 65e6,
                paper_edges: 1.8e9,
                paper_largest_cc: 65e6,
                avg_deg: 28.0,
                largest_cc_frac: 1.0,
                default_n: 80_000,
            },
            "clueweb" => DatasetSpec {
                name: "clueweb",
                paper_nodes: 955e6,
                paper_edges: 37e9,
                paper_largest_cc: 950e6,
                avg_deg: 39.0,
                largest_cc_frac: 0.995,
                default_n: 100_000,
            },
            "videos" => DatasetSpec {
                name: "videos",
                paper_nodes: 92e9,
                paper_edges: 626e9,
                paper_largest_cc: 18e9,
                avg_deg: 6.8,
                largest_cc_frac: 0.20,
                default_n: 120_000,
            },
            "webpages" => DatasetSpec {
                name: "webpages",
                paper_nodes: 854e9,
                paper_edges: 6.5e12,
                paper_largest_cc: 7e9,
                avg_deg: 7.6,
                largest_cc_frac: 0.008,
                default_n: 150_000,
            },
            other => panic!("unknown dataset preset {other:?}"),
        }
    }

    /// Generate the analogue at `n` vertices (None = the preset default).
    pub fn generate(name: &str, n: Option<usize>, seed: u64) -> Graph {
        let s = spec(name);
        let n = n.unwrap_or(s.default_n);
        let mut rng = Rng::new(seed ^ crate::util::rng::splitmix64(name.len() as u64));
        match name {
            // Single giant social component, power-law degrees.
            "orkut" | "friendster" => {
                let mpv = (s.avg_deg / 2.0).round() as usize;
                preferential_attachment(n, mpv.max(1), &mut rng)
            }
            // Webgraph: R-MAT skew (isolated vertices + one dominant CC).
            "clueweb" => {
                let scale = (n as f64).log2().ceil() as u32;
                let m = (n as f64 * s.avg_deg / 2.0) as usize;
                rmat(scale, m, (0.57, 0.19, 0.19, 0.05), &mut rng)
            }
            // Similarity graphs: many components with a bounded largest CC.
            "videos" | "webpages" => component_mixture(
                n,
                s.largest_cc_frac,
                s.avg_deg,
                &mut rng,
            ),
            other => panic!("unknown dataset preset {other:?}"),
        }
    }

    /// Mixture of connected components: one of size `largest_frac * n`,
    /// the rest drawn from a Pareto-ish size distribution — the shape of
    /// the paper's entity-similarity graphs (videos/webpages rows).
    pub fn component_mixture(
        n: usize,
        largest_frac: f64,
        avg_deg: f64,
        rng: &mut Rng,
    ) -> Graph {
        let largest = ((n as f64 * largest_frac) as usize).max(2).min(n);
        let mut g = connected_component(largest, avg_deg, rng);
        let mut remaining = n - largest;
        while remaining > 0 {
            // Pareto(α≈1.5) component sizes, capped below the largest.
            let u = rng.next_f64().max(1e-12);
            let size = ((2.0 / u.powf(1.0 / 1.5)) as usize)
                .clamp(1, largest.saturating_sub(1).max(1))
                .min(remaining);
            let c = if size == 1 {
                Graph::empty(1)
            } else {
                connected_component(size, avg_deg.min(size as f64 - 1.0), rng)
            };
            g = g.disjoint_union(c);
            remaining -= size;
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::dsu::DisjointSet;

    fn components(g: &Graph) -> DisjointSet {
        let mut d = DisjointSet::new(g.num_vertices());
        for &(u, v) in g.edges() {
            d.union(u, v);
        }
        d
    }

    #[test]
    fn structured_shapes() {
        assert_eq!(path(5).num_edges(), 4);
        assert_eq!(cycle(5).num_edges(), 5);
        assert_eq!(star(5).num_edges(), 4);
        assert_eq!(complete(5).num_edges(), 10);
        assert_eq!(grid(3, 4).num_edges(), 2 * 4 + 3 * 3); // 17
        assert_eq!(binary_tree(7).num_edges(), 6);
        let cat = caterpillar(4, 2);
        assert_eq!(cat.num_vertices(), 12);
        assert_eq!(cat.num_edges(), 3 + 8);
    }

    #[test]
    fn one_or_two_cycles_component_counts() {
        assert_eq!(components(&one_or_two_cycles(10, false)).components(), 1);
        assert_eq!(components(&one_or_two_cycles(10, true)).components(), 2);
    }

    #[test]
    fn gnp_density_close_to_p() {
        let mut rng = Rng::new(1);
        let (n, p) = (500, 0.02);
        let g = gnp(n, p, &mut rng);
        let expected = p * (n * (n - 1) / 2) as f64;
        let got = g.num_edges() as f64;
        assert!(
            (got - expected).abs() < 0.15 * expected,
            "edges {got} vs expected {expected}"
        );
    }

    #[test]
    fn gnp_extremes() {
        let mut rng = Rng::new(2);
        assert_eq!(gnp(50, 0.0, &mut rng).num_edges(), 0);
        assert_eq!(gnp(20, 1.0, &mut rng).num_edges(), 190);
    }

    #[test]
    fn gnp_log_regime_is_connected_whp() {
        let mut rng = Rng::new(3);
        let g = gnp_log_regime(2000, 4.0, &mut rng);
        assert_eq!(components(&g).components(), 1);
    }

    #[test]
    fn gnp_class_superset_contains_extra() {
        let mut rng = Rng::new(4);
        let extra = vec![(0, 999)];
        let g = gnp_class(1000, 0.001, &extra, &mut rng);
        assert!(g.edges().contains(&(0, 999)));
    }

    #[test]
    fn chung_lu_has_heavy_tail() {
        let mut rng = Rng::new(5);
        let g = chung_lu(5000, 10.0, 2.5, &mut rng);
        let deg = g.degrees();
        let max = *deg.iter().max().unwrap() as f64;
        let avg = deg.iter().map(|&d| d as f64).sum::<f64>() / deg.len() as f64;
        assert!(avg > 5.0 && avg < 15.0, "avg {avg}");
        assert!(max > 8.0 * avg, "max {max} not heavy-tailed vs avg {avg}");
    }

    #[test]
    fn preferential_attachment_is_connected() {
        let mut rng = Rng::new(6);
        let g = preferential_attachment(3000, 3, &mut rng);
        assert_eq!(components(&g).components(), 1);
        let deg = g.degrees();
        assert!(*deg.iter().max().unwrap() > 30);
    }

    #[test]
    fn preferential_attachment_realizes_full_density() {
        // Regression: duplicate targets for one source used to collapse
        // under normalize(), silently starving the realized density.
        // Distinct in-range targets per batch make the normalized edge
        // count exactly sum_v min(m, v).
        for seed in [1, 11, 42] {
            for (n, m) in [(200usize, 3usize), (400, 8), (50, 60)] {
                let g = preferential_attachment(n, m, &mut Rng::new(seed));
                let want: usize = (1..n).map(|v| m.min(v)).sum();
                assert_eq!(
                    g.num_edges(),
                    want,
                    "n={n} m={m} seed={seed}: batches must be duplicate- and loop-free"
                );
                assert!(g.edges().iter().all(|&(u, v)| u != v), "self edge");
                // realized density == target implies avg degree ~ 2m once
                // n >> m; spot-check the usual regime
                if n > 10 * m {
                    let avg = 2.0 * g.num_edges() as f64 / n as f64;
                    assert!(
                        (avg - 2.0 * m as f64).abs() < 0.2 * m as f64,
                        "n={n} m={m}: avg degree {avg} vs target {}",
                        2 * m
                    );
                }
            }
        }
        // m > n exercises the bounded-retry fallback on every batch: the
        // result must be the complete graph
        let g = preferential_attachment(50, 60, &mut Rng::new(9));
        assert_eq!(g.num_edges(), 50 * 49 / 2);
    }

    #[test]
    fn rmat_shape() {
        let mut rng = Rng::new(7);
        let g = rmat(10, 5000, (0.57, 0.19, 0.19, 0.05), &mut rng);
        assert_eq!(g.num_vertices(), 1024);
        assert!(g.num_edges() > 3000); // some dedup/self-loop loss ok
    }

    #[test]
    fn connected_component_is_connected() {
        let mut rng = Rng::new(8);
        let g = connected_component(500, 6.0, &mut rng);
        assert_eq!(components(&g).components(), 1);
        let avg = 2.0 * g.num_edges() as f64 / 500.0;
        assert!(avg > 3.0, "avg degree {avg}");
    }

    #[test]
    fn presets_generate_and_match_shape() {
        for name in presets::ALL {
            let spec = presets::spec(name);
            let g = presets::generate(name, Some(5000), 42);
            assert!(g.num_vertices() >= 5000, "{name}");
            let mut d = components(&g);
            let largest = (0..g.num_vertices() as u32)
                .map(|v| d.set_size(v))
                .max()
                .unwrap() as f64;
            let frac = largest / g.num_vertices() as f64;
            // loose structural check: giant components stay giant, highly
            // fragmented presets stay fragmented
            if spec.largest_cc_frac >= 0.99 {
                assert!(frac > 0.6, "{name}: largest CC frac {frac}");
            } else if spec.largest_cc_frac <= 0.01 {
                assert!(frac < 0.2, "{name}: largest CC frac {frac}");
            }
        }
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let a = presets::generate("orkut", Some(1000), 7);
        let b = presets::generate("orkut", Some(1000), 7);
        let c = presets::generate("orkut", Some(1000), 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
