//! Compressed sparse row adjacency, built from an edge list.
//!
//! Used wherever per-vertex neighborhood iteration is the access pattern:
//! BFS-based statistics, the dense-shard packer, and the single-machine
//! reference implementations of the per-phase label computations.

use super::edgelist::{Graph, Vertex};
use super::sharded::ShardedGraph;

/// Symmetric CSR adjacency (each undirected edge appears in both rows).
#[derive(Debug, Clone)]
pub struct Csr {
    offsets: Vec<usize>,
    nbrs: Vec<Vertex>,
}

impl Csr {
    /// Build from any two-pass edge source.  Rows are sorted, so the
    /// result depends only on the edge *set* — flat and sharded sources
    /// yield identical adjacencies.
    fn build_from<I, F>(n: usize, edges: F) -> Csr
    where
        I: Iterator<Item = (Vertex, Vertex)>,
        F: Fn() -> I,
    {
        let mut deg = vec![0usize; n + 1];
        for (u, v) in edges() {
            deg[u as usize + 1] += 1;
            deg[v as usize + 1] += 1;
        }
        for i in 0..n {
            deg[i + 1] += deg[i];
        }
        let offsets = deg.clone();
        let mut cursor = deg;
        let mut nbrs = vec![0 as Vertex; offsets[n]];
        for (u, v) in edges() {
            nbrs[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            nbrs[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // Sort each row for deterministic iteration + binary-searchable rows.
        let mut csr = Csr { offsets, nbrs };
        for v in 0..n {
            let (s, e) = (csr.offsets[v], csr.offsets[v + 1]);
            csr.nbrs[s..e].sort_unstable();
        }
        csr
    }

    pub fn build(g: &Graph) -> Csr {
        Self::build_from(g.num_vertices(), || g.edges().iter().copied())
    }

    /// Build straight from the sharded store — no flattening.
    pub fn build_sharded(g: &ShardedGraph) -> Csr {
        Self::build_from(g.num_vertices(), || g.iter_edges())
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn neighbors(&self, v: Vertex) -> &[Vertex] {
        &self.nbrs[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    #[inline]
    pub fn degree(&self, v: Vertex) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// BFS from `src`; returns (distance array, farthest vertex).
    /// Unreachable vertices get `u32::MAX`.
    pub fn bfs(&self, src: Vertex) -> (Vec<u32>, Vertex) {
        let n = self.num_vertices();
        let mut dist = vec![u32::MAX; n];
        let mut queue = std::collections::VecDeque::new();
        dist[src as usize] = 0;
        queue.push_back(src);
        let mut far = src;
        while let Some(v) = queue.pop_front() {
            for &u in self.neighbors(v) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = dist[v as usize] + 1;
                    if dist[u as usize] > dist[far as usize] {
                        far = u;
                    }
                    queue.push_back(u);
                }
            }
        }
        (dist, far)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(n: usize) -> Graph {
        Graph::from_edges(n, (1..n as u32).map(|v| (v - 1, v)).collect())
    }

    #[test]
    fn neighbors_of_path() {
        let csr = Csr::build(&path(4));
        assert_eq!(csr.neighbors(0), &[1]);
        assert_eq!(csr.neighbors(1), &[0, 2]);
        assert_eq!(csr.neighbors(3), &[2]);
        assert_eq!(csr.degree(1), 2);
    }

    #[test]
    fn rows_are_sorted() {
        let g = Graph::from_edges(5, vec![(0, 4), (0, 2), (0, 1), (0, 3)]);
        let csr = Csr::build(&g);
        assert_eq!(csr.neighbors(0), &[1, 2, 3, 4]);
    }

    #[test]
    fn bfs_distances_on_path() {
        let csr = Csr::build(&path(5));
        let (dist, far) = csr.bfs(0);
        assert_eq!(dist, vec![0, 1, 2, 3, 4]);
        assert_eq!(far, 4);
    }

    #[test]
    fn bfs_unreachable_is_max() {
        let g = Graph::from_edges(4, vec![(0, 1)]);
        let csr = Csr::build(&g);
        let (dist, _) = csr.bfs(0);
        assert_eq!(dist[2], u32::MAX);
        assert_eq!(dist[3], u32::MAX);
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::build(&Graph::empty(3));
        assert_eq!(csr.num_vertices(), 3);
        assert_eq!(csr.neighbors(0), &[] as &[Vertex]);
    }

    #[test]
    fn sharded_build_matches_flat_build() {
        let mut rng = crate::util::rng::Rng::new(5);
        let raw: Vec<(Vertex, Vertex)> = (0..500)
            .map(|_| (rng.gen_range(60) as Vertex, rng.gen_range(60) as Vertex))
            .collect();
        let flat = Graph::from_edges(60, raw.clone());
        let sharded = ShardedGraph::from_edges(60, 4, raw);
        let a = Csr::build(&flat);
        let b = Csr::build_sharded(&sharded);
        for v in 0..60u32 {
            assert_eq!(a.neighbors(v), b.neighbors(v), "row {v}");
        }
    }
}
