//! Undirected edge-list graph representation.
//!
//! This is the wire format of the whole system: the MPC simulator shuffles
//! edges, the contraction step rewrites them, and the generators emit them.
//! Vertices are dense `u32` ids `0..n`; edges are stored canonically as
//! `(min, max)` with no self-loops after [`Graph::normalize`].

pub type Vertex = u32;

/// Dense rank table over the image of `labels` within `0..universe`:
/// returns `(rank_of, count)` where `rank_of[l]` is the index of label `l`
/// in the ascending sequence of distinct labels (slots of absent labels
/// are 0 and must not be read) and `count` is the number of distinct
/// labels.  O(n + universe) — shared by [`Graph::contract`] and the MPC
/// contraction (`cc::common::contract_mpc`) in place of the former
/// per-edge `binary_search` (§Perf).
///
/// Every value in `labels` must be `< universe`.
pub fn label_ranks(labels: &[Vertex], universe: usize) -> (Vec<Vertex>, usize) {
    let mut present = vec![false; universe];
    for &l in labels {
        present[l as usize] = true;
    }
    let mut rank_of = vec![0 as Vertex; universe];
    let mut next = 0u32;
    for l in 0..universe {
        if present[l] {
            rank_of[l] = next;
            next += 1;
        }
    }
    (rank_of, next as usize)
}

/// Compact a label vector to dense ids `0..count`, preserving label order
/// (so canonical minimum labels stay comparable across phases).  The usual
/// case (labels are vertex ids, so values ~< n) uses the O(n) dense rank
/// table; wildly sparse label values fall back to sort + binary-search
/// rather than allocating a huge table.
///
/// Shared by [`Graph::contract`] and [`super::sharded::ShardedGraph::contract`]
/// so both representations produce **bit-identical** compaction maps.
pub fn compact_labels(labels: &[Vertex], n: usize) -> (Vec<Vertex>, usize) {
    let universe = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
    if universe <= n.saturating_mul(4).max(1024) {
        let (rank_of, count) = label_ranks(labels, universe);
        (
            labels.iter().map(|&l| rank_of[l as usize]).collect(),
            count,
        )
    } else {
        let mut sorted: Vec<Vertex> = labels.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        (
            labels
                .iter()
                .map(|&l| sorted.binary_search(&l).unwrap() as Vertex)
                .collect(),
            sorted.len(),
        )
    }
}

/// An undirected graph as `n` vertex slots plus an edge list.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    n: usize,
    edges: Vec<(Vertex, Vertex)>,
}

impl Graph {
    /// Empty graph on `n` vertices.
    pub fn empty(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        Graph { n, edges: Vec::new() }
    }

    /// Build from raw edges; normalizes (canonical order, dedup, no loops).
    pub fn from_edges(n: usize, edges: Vec<(Vertex, Vertex)>) -> Self {
        let mut g = Graph { n, edges };
        g.normalize();
        g
    }

    /// Build without normalizing (for internal steps that guarantee shape).
    pub fn from_edges_unchecked(n: usize, edges: Vec<(Vertex, Vertex)>) -> Self {
        Graph { n, edges }
    }

    pub fn num_vertices(&self) -> usize {
        self.n
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[(Vertex, Vertex)] {
        &self.edges
    }

    pub fn into_edges(self) -> Vec<(Vertex, Vertex)> {
        self.edges
    }

    pub fn add_edge(&mut self, u: Vertex, v: Vertex) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.edges.push((u, v));
    }

    /// Canonicalize to `(min,max)`, drop self-loops, sort + dedup.
    ///
    /// The sort runs after every contraction phase, so it is a system hot
    /// spot: large lists pack each edge into a `u64` (`u << 32 | v`, which
    /// preserves lexicographic pair order) and go through the parallel
    /// radix sort; small lists keep the comparison sort (§Perf).
    pub fn normalize(&mut self) {
        for e in &mut self.edges {
            assert!(
                (e.0 as usize) < self.n && (e.1 as usize) < self.n,
                "edge ({},{}) out of range n={}",
                e.0,
                e.1,
                self.n
            );
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
        }
        self.edges.retain(|e| e.0 != e.1);
        crate::util::radix::par_sort_edge_pairs(&mut self.edges, true);
    }

    /// Per-vertex degree (normalized-graph semantics: no loops, no multi-edges).
    pub fn degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n];
        for &(u, v) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        deg
    }

    /// Vertices with degree zero.
    pub fn isolated_vertices(&self) -> Vec<Vertex> {
        self.degrees()
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(v, _)| v as Vertex)
            .collect()
    }

    /// Disjoint union with `other`: vertices of `other` are shifted by
    /// `self.n`.  Used by the dataset presets to assemble many-component
    /// mixtures (videos/webpages analogues).
    pub fn disjoint_union(mut self, other: Graph) -> Graph {
        let off = self.n as u32;
        self.n += other.n;
        assert!(self.n <= u32::MAX as usize);
        self.edges
            .extend(other.edges.into_iter().map(|(u, v)| (u + off, v + off)));
        self
    }

    /// Apply a vertex relabeling `label[v]` and compact to the image space.
    ///
    /// This is the *contraction* G/r of §2: vertices with equal labels merge
    /// into one node; self-loops and duplicate edges vanish in `normalize`.
    /// Returns the contracted graph plus `compact`, mapping each old vertex
    /// to its node id in the new graph.
    pub fn contract(&self, labels: &[Vertex]) -> (Graph, Vec<Vertex>) {
        assert_eq!(labels.len(), self.n, "labels len != n");
        let (compact, count) = compact_labels(labels, self.n);
        let edges: Vec<(Vertex, Vertex)> = self
            .edges
            .iter()
            .map(|&(u, v)| (compact[u as usize], compact[v as usize]))
            .collect();
        (Graph::from_edges(count, edges), compact)
    }

    /// Drop isolated vertices, compacting ids.  Returns the pruned graph and
    /// the mapping old-id -> Some(new-id) (None for dropped vertices).
    ///
    /// §6: "after each phase we can get rid of all isolated nodes from the
    /// contracted graph, as their connected component assignment is clear."
    pub fn prune_isolated(&self) -> (Graph, Vec<Option<Vertex>>) {
        let deg = self.degrees();
        let mut map = vec![None; self.n];
        let mut next = 0u32;
        for v in 0..self.n {
            if deg[v] > 0 {
                map[v] = Some(next);
                next += 1;
            }
        }
        let edges = self
            .edges
            .iter()
            .map(|&(u, v)| (map[u as usize].unwrap(), map[v as usize].unwrap()))
            .collect();
        (Graph::from_edges_unchecked(next as usize, edges), map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_dedups_and_drops_loops() {
        let g = Graph::from_edges(4, vec![(1, 0), (0, 1), (2, 2), (3, 2)]);
        assert_eq!(g.edges(), &[(0, 1), (2, 3)]);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn normalize_rejects_out_of_range() {
        Graph::from_edges(2, vec![(0, 5)]);
    }

    #[test]
    fn degrees_and_isolated() {
        let g = Graph::from_edges(5, vec![(0, 1), (1, 2)]);
        assert_eq!(g.degrees(), vec![1, 2, 1, 0, 0]);
        assert_eq!(g.isolated_vertices(), vec![3, 4]);
    }

    #[test]
    fn disjoint_union_shifts() {
        let a = Graph::from_edges(2, vec![(0, 1)]);
        let b = Graph::from_edges(3, vec![(0, 2)]);
        let u = a.disjoint_union(b);
        assert_eq!(u.num_vertices(), 5);
        assert_eq!(u.edges(), &[(0, 1), (2, 4)]);
    }

    #[test]
    fn contract_merges_label_classes() {
        // path 0-1-2-3, merge {0,1} and {2,3}
        let g = Graph::from_edges(4, vec![(0, 1), (1, 2), (2, 3)]);
        let (c, compact) = g.contract(&[0, 0, 2, 2]);
        assert_eq!(c.num_vertices(), 2);
        assert_eq!(c.edges(), &[(0, 1)]); // loops gone, dedup
        assert_eq!(compact, vec![0, 0, 1, 1]);
    }

    #[test]
    fn contract_preserves_label_order() {
        let g = Graph::from_edges(3, vec![(0, 1), (1, 2)]);
        // labels 5 and 9: node ids must be rank-ordered 5->0, 9->1
        let (c, compact) = g.contract(&[9, 5, 5]);
        assert_eq!(c.num_vertices(), 2);
        assert_eq!(compact, vec![1, 0, 0]);
    }

    #[test]
    fn prune_isolated_compacts() {
        let g = Graph::from_edges(5, vec![(1, 3)]);
        let (p, map) = g.prune_isolated();
        assert_eq!(p.num_vertices(), 2);
        assert_eq!(p.edges(), &[(0, 1)]);
        assert_eq!(map, vec![None, Some(0), None, Some(1), None]);
    }

    #[test]
    fn label_ranks_match_sorted_dedup() {
        let labels = vec![9u32, 5, 5, 0, 9, 3];
        let (rank_of, count) = label_ranks(&labels, 10);
        assert_eq!(count, 4); // {0, 3, 5, 9}
        assert_eq!(rank_of[0], 0);
        assert_eq!(rank_of[3], 1);
        assert_eq!(rank_of[5], 2);
        assert_eq!(rank_of[9], 3);
    }

    #[test]
    fn contract_sparse_labels_use_fallback() {
        // max label far above 4n + 1024: exercises the binary-search path
        let g = Graph::from_edges(3, vec![(0, 1), (1, 2)]);
        let (c, compact) = g.contract(&[1_000_000, 5, 5]);
        assert_eq!(c.num_vertices(), 2);
        assert_eq!(compact, vec![1, 0, 0]);
        assert_eq!(c.edges(), &[(0, 1)]);
    }

    #[test]
    fn normalize_large_list_matches_comparison_sort() {
        // Above the radix threshold: same canonical result as a small-list
        // normalize of the same multiset.
        let mut rng = crate::util::rng::Rng::new(11);
        let n = 500u64;
        let raw: Vec<(Vertex, Vertex)> = (0..10_000)
            .map(|_| (rng.gen_range(n) as Vertex, rng.gen_range(n) as Vertex))
            .collect();
        let fast = Graph::from_edges(n as usize, raw.clone());

        let mut slow: Vec<(Vertex, Vertex)> = raw
            .into_iter()
            .map(|(u, v)| if u > v { (v, u) } else { (u, v) })
            .filter(|&(u, v)| u != v)
            .collect();
        slow.sort_unstable();
        slow.dedup();
        assert_eq!(fast.edges(), &slow[..]);
    }

    #[test]
    fn contract_to_single_node_has_no_edges() {
        let g = Graph::from_edges(3, vec![(0, 1), (1, 2), (0, 2)]);
        let (c, _) = g.contract(&[7, 7, 7]);
        assert_eq!(c.num_vertices(), 1);
        assert_eq!(c.num_edges(), 0);
    }
}
