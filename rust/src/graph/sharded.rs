//! The sharded edge store: the machine partition as the system's
//! **resident** graph representation — with optional disk residency.
//!
//! The paper's contractions scale to trillions of edges because no machine
//! ever holds the full edge list.  This module makes that layout native:
//! a [`ShardedGraph`] owns its edges as one [`EdgeShard`] per simulated
//! machine, where the canonical edge `(u, v)` (`u < v`) lives on machine
//! `machine_of(u)` — the same stable hash the MPC shuffle rounds use.
//!
//! **Shard-ownership invariant.**  For every shard `s` and every edge
//! `(u, v)` stored there: `u < v` and `machine_of(u, p) == s`, the shard's
//! edge list is sorted and duplicate-free, and two cached histograms are
//! maintained alongside the edges:
//!
//! * `peer_counts[j]` — edges of the shard whose *right* endpoint is owned
//!   by machine `j` (the destination of the second message of every hop
//!   and of the second contraction round);
//! * `vertex_counts[j]` — vertices `v ∈ 0..n` with `machine_of(v) == j`
//!   (the destinations of the per-vertex self messages).
//!
//! Because the partition function is the message-key hash, the exact
//! per-machine byte loads of every hop and contraction round are **pure
//! functions of these shard statistics** ([`ShardedGraph::hop_charge`],
//! [`ShardedGraph::contract_charges`]) — the round engine never recomputes
//! `machine_of` per message.
//!
//! **Residency.**  Shards live behind a [`super::spill::ShardStore`]
//! backend chosen by the graph's [`SpillPolicy`]: fully in RAM
//! ([`super::spill::Resident`]) while the edge set fits the memory budget,
//! or one checksummed file per shard ([`super::spill::Spilled`]) once it
//! does not — with only the cached histograms resident.  Mutating
//! operations (`contract`, `prune_isolated`, `reshard`,
//! [`ShardedGraph::from_edges`]) re-bucket rewritten edges into their new
//! owner shards; on a spilled source this runs **load → rewrite → spill**
//! one shard per worker through per-destination staging files
//! (`rewrite_streamed`), so the full edge set never materializes in RAM.
//! Both paths produce bit-identical graphs — enforced by
//! `rust/tests/spill_equivalence.rs`.
//!
//! [`Graph`] remains the flat ingest/oracle format; [`ShardedGraph::to_graph`]
//! is the thin conversion back (bit-identical to a monolithic
//! `Graph::normalize` of the same edge multiset — enforced by
//! `rust/tests/sharded_representation.rs`).

use std::path::Path;
use std::sync::{Arc, Mutex};

use super::edgelist::{compact_labels, Graph, Vertex};
use super::spill::{
    self, EdgeShard, Resident, ShardData, ShardDataIter, ShardStats, ShardStore, SpillDir,
    SpillError, SpillPolicy, Spilled, SpilledShard,
};
use crate::mpc::pool::{self, chunk_range};
use crate::mpc::simulator::{machine_of, ShardRound};

/// The two [`ShardStore`] backends, dispatched statically.
#[derive(Debug, Clone)]
enum Store {
    Resident(Resident),
    Spilled(Spilled),
}

impl Store {
    fn as_store(&self) -> &dyn ShardStore {
        match self {
            Store::Resident(r) => r,
            Store::Spilled(s) => s,
        }
    }
}

/// An undirected graph resident as `machines` edge shards (see module docs
/// for the ownership and residency invariants).
#[derive(Debug, Clone)]
pub struct ShardedGraph {
    n: usize,
    store: Store,
    /// `vertex_counts[j]` = vertices of `0..n` owned by machine `j`.
    vertex_counts: Vec<u64>,
    /// Residency policy inherited by every derived generation.
    policy: SpillPolicy,
    /// Process-unique generation id: every rewrite (contract, prune,
    /// reshard, fresh ingest) mints a new one; clones share it (same
    /// content).  The shuffle transport keys worker shard custody on it —
    /// an O(1) "is this the graph the workers hold?" check, never a
    /// content hash.  Not part of equality.
    gen: u64,
}

/// Mint a generation id (see [`ShardedGraph::generation`]).
fn next_gen() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Content equality across backends: same vertex universe, shard count,
/// per-shard statistics, and per-shard edges.  The policy and backend are
/// *not* part of the identity.  For spilled shards, inequality is decided
/// from the RAM-cached payload checksums without touching disk (a
/// checksum over the canonical payload differs ⇒ the edges differ);
/// payloads are loaded and compared only when the checksums agree, so a
/// convergence check like Two-Phase's `next == cur` pays disk I/O only on
/// the round that actually converged.
impl PartialEq for ShardedGraph {
    fn eq(&self, other: &ShardedGraph) -> bool {
        if self.n != other.n
            || self.num_shards() != other.num_shards()
            || self.vertex_counts != other.vertex_counts
        {
            return false;
        }
        for s in 0..self.num_shards() {
            if self.store.as_store().stats(s) != other.store.as_store().stats(s) {
                return false;
            }
            if let (Some(a), Some(b)) = (self.shard_checksum(s), other.shard_checksum(s)) {
                if a != b {
                    return false; // sound negative: no disk read needed
                }
            }
            if !self.shard_data(s).iter().eq(other.shard_data(s).iter()) {
                return false;
            }
        }
        true
    }
}

/// `machine_of` histogram of the vertex ids `0..n` (self-message loads),
/// computed in parallel chunks merged in fixed order.
fn vertex_counts(n: usize, p: usize) -> Vec<u64> {
    let t = pool::global()
        .threads()
        .clamp(1, n.div_ceil(1 << 14).max(1));
    if t <= 1 {
        let mut h = vec![0u64; p];
        for v in 0..n {
            h[machine_of(v as u64, p)] += 1;
        }
        return h;
    }
    let parts = pool::global().run_jobs(
        (0..t)
            .map(|i| {
                let (a, b) = chunk_range(n, t, i);
                move || {
                    let mut h = vec![0u64; p];
                    for v in a..b {
                        h[machine_of(v as u64, p)] += 1;
                    }
                    h
                }
            })
            .collect(),
    );
    let mut h = vec![0u64; p];
    for part in parts {
        for (a, b) in h.iter_mut().zip(&part) {
            *a += b;
        }
    }
    h
}

/// Load every shard of a spilled store back into RAM, pool-parallel,
/// reusing the RAM-cached stats (a pure read: no re-hash).  The inverse
/// of [`spill_finished`], shared by the un-spill paths.
fn unspill_all(sp: &Spilled) -> Result<Vec<EdgeShard>, SpillError> {
    let p = sp.num_shards();
    let t = pool::global().threads().clamp(1, p);
    let jobs: Vec<_> = (0..t)
        .map(|i| {
            let (a, b) = chunk_range(p, t, i);
            move || -> Result<Vec<EdgeShard>, SpillError> {
                (a..b)
                    .map(|s| {
                        Ok(EdgeShard::with_stats(
                            sp.read(s)?.into_vec(),
                            sp.shard_metas()[s].stats.clone(),
                            p,
                            s,
                        ))
                    })
                    .collect()
            }
        })
        .collect();
    let mut shards = Vec::with_capacity(p);
    for part in pool::global().run_jobs(jobs) {
        shards.extend(part?);
    }
    Ok(shards)
}

/// Spill finalized shards to a fresh generation directory, shard-parallel.
fn spill_finished(
    shards: Vec<EdgeShard>,
    policy: &SpillPolicy,
) -> Result<Store, SpillError> {
    let p = shards.len();
    let dir = Arc::new(SpillDir::create_temp(policy.root.as_deref())?);
    let t = pool::global().threads().clamp(1, p);
    let mut it = shards.into_iter().enumerate();
    let mut jobs = Vec::with_capacity(t);
    for i in 0..t {
        let (a, b) = chunk_range(p, t, i);
        let group: Vec<(usize, EdgeShard)> = it.by_ref().take(b - a).collect();
        let dir = Arc::clone(&dir);
        jobs.push(move || -> Result<Vec<SpilledShard>, SpillError> {
            group
                .into_iter()
                .map(|(s, shard)| spill::spill_shard(&dir, s, p, &shard))
                .collect()
        });
    }
    let mut metas = Vec::with_capacity(p);
    for part in pool::global().run_jobs(jobs) {
        metas.extend(part?);
    }
    Ok(Store::Spilled(Spilled::from_parts(dir, metas)))
}

/// Finalize per-shard buckets into a canonical [`ShardedGraph`]:
/// canonicalize each edge to `(min, max)`, drop self-loops, sort + dedup
/// within the shard (equal edges always share a shard, so per-shard dedup
/// *is* global dedup), and compute the cached peer histogram — one pass,
/// shard-parallel on the worker pool.  Bucket `s` must only contain edges
/// it owns (`machine_of(min endpoint) == s`; enforced in debug builds).
/// `cached_vertex_counts` may carry the histogram of a previous graph
/// over the **same** `(n, p)` — it is a pure function of those two, so
/// per-round rebuilds skip the O(n) re-hash.  When the finalized edge set
/// exceeds the policy budget, the shards are written out and dropped.
fn finish_shards(
    n: usize,
    buckets: Vec<Vec<(Vertex, Vertex)>>,
    cached_vertex_counts: Option<Vec<u64>>,
    policy: &SpillPolicy,
) -> Result<ShardedGraph, SpillError> {
    let p = buckets.len();
    let t = pool::global().threads().clamp(1, p);
    let mut it = buckets.into_iter().enumerate();
    let mut jobs = Vec::with_capacity(t);
    for i in 0..t {
        let (a, b) = chunk_range(p, t, i);
        let group: Vec<(usize, Vec<(Vertex, Vertex)>)> = it.by_ref().take(b - a).collect();
        jobs.push(move || {
            group
                .into_iter()
                .map(|(s, mut edges)| {
                    for e in edges.iter_mut() {
                        if e.0 > e.1 {
                            *e = (e.1, e.0);
                        }
                    }
                    edges.retain(|e| e.0 != e.1);
                    edges.sort_unstable();
                    edges.dedup();
                    EdgeShard::new_canonical(edges, p, s)
                })
                .collect::<Vec<EdgeShard>>()
        });
    }
    let shards: Vec<EdgeShard> = pool::global()
        .run_jobs(jobs)
        .into_iter()
        .flatten()
        .collect();
    let total_bytes: u64 = shards.iter().map(|s| s.len() as u64 * spill::EDGE_BYTES).sum();
    let store = if policy.should_spill(total_bytes) {
        spill_finished(shards, policy)?
    } else {
        Store::Resident(Resident::new(shards))
    };
    let vertex_counts = match cached_vertex_counts {
        Some(counts) => {
            debug_assert_eq!(counts.len(), p);
            debug_assert_eq!(counts.iter().sum::<u64>(), n as u64);
            counts
        }
        None => vertex_counts(n, p),
    };
    Ok(ShardedGraph {
        n,
        store,
        vertex_counts,
        policy: policy.clone(),
        gen: next_gen(),
    })
}

/// A lazily-materialized message chunk over rows `lo..hi` of one shard
/// (see [`ShardedGraph::msg_chunks`] /
/// [`msg_chunks_split`](ShardedGraph::msg_chunks_split)): the shard is
/// read — for spilled backends, mmap'd — on the worker that *iterates*
/// the chunk, and a mapped shard hands each worker a borrowed
/// [`ShardCursor`](super::spill::ShardCursor) slice over the shared
/// image, so splitting one spilled shard across threads costs no copy.
/// Exactly one chunk per shard carries `primary == true`; per-shard
/// extras (the self-message range) must chain onto the primary chunk
/// only, so splitting never duplicates per-vertex messages.
pub struct ShardMsgChunk<'g, M> {
    g: &'g ShardedGraph,
    s: usize,
    lo: usize,
    hi: usize,
    primary: bool,
    make: M,
}

impl<'g, M, I> IntoIterator for ShardMsgChunk<'g, M>
where
    M: FnOnce(usize, bool, ShardDataIter<'g>) -> I,
    I: Iterator,
{
    type Item = I::Item;
    type IntoIter = I;
    fn into_iter(self) -> I {
        (self.make)(
            self.s,
            self.primary,
            self.g.shard_data(self.s).into_range_iter(self.lo, self.hi),
        )
    }
}

impl ShardedGraph {
    /// Empty graph on `n` vertices over `p` shards (`p` is clamped to 1).
    pub fn empty(n: usize, p: usize) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        let p = p.max(1);
        ShardedGraph {
            n,
            store: Store::Resident(Resident::new(
                (0..p)
                    .map(|s| EdgeShard::new_canonical(Vec::new(), p, s))
                    .collect(),
            )),
            vertex_counts: vertex_counts(n, p),
            policy: SpillPolicy::unbounded(),
            gen: next_gen(),
        }
    }

    /// Build from raw edges: bucket each edge to its owner shard
    /// (`machine_of(min endpoint)`) in parallel chunks, then normalize
    /// shard-locally (canonical order, per-shard sort + dedup, no loops) —
    /// no global sort anywhere.  Unbounded residency; see
    /// [`from_edges_with`](Self::from_edges_with) for a budgeted build.
    pub fn from_edges(n: usize, p: usize, edges: Vec<(Vertex, Vertex)>) -> Self {
        Self::from_edges_with(n, p, edges, SpillPolicy::unbounded())
    }

    /// [`from_edges`](Self::from_edges) under a residency policy: the
    /// built graph (and every generation derived from it) spills to disk
    /// whenever its edge set exceeds the policy budget.
    pub fn from_edges_with(
        n: usize,
        p: usize,
        edges: Vec<(Vertex, Vertex)>,
        policy: SpillPolicy,
    ) -> Self {
        Self::from_edges_cached(n, p, edges, None, policy)
            .unwrap_or_else(|e| panic!("shard spill failed during ingest: {e}"))
    }

    /// [`from_edges`](Self::from_edges) over the **same vertex universe
    /// and shard count** as `self`, reusing its cached vertex ownership
    /// histogram and residency policy — the per-round rebuild path
    /// (Cracker's rewire, Two-Phase's star rounds) skips n `machine_of`
    /// hashes each round.
    pub fn from_edges_like(&self, edges: Vec<(Vertex, Vertex)>) -> Self {
        Self::from_edges_cached(
            self.n,
            self.num_shards(),
            edges,
            Some(self.vertex_counts.clone()),
            self.policy.clone(),
        )
        .unwrap_or_else(|e| panic!("shard spill failed during rebuild: {e}"))
    }

    fn from_edges_cached(
        n: usize,
        p: usize,
        edges: Vec<(Vertex, Vertex)>,
        cached_vertex_counts: Option<Vec<u64>>,
        policy: SpillPolicy,
    ) -> Result<Self, SpillError> {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        let p = p.max(1);
        let len = edges.len();
        let t = pool::global()
            .threads()
            .clamp(1, len.div_ceil(1 << 14).max(1));
        let edges_ro: &[(Vertex, Vertex)] = &edges;
        let parts: Vec<Vec<Vec<(Vertex, Vertex)>>> = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(len, t, i);
                    let part = &edges_ro[a..b];
                    move || {
                        let mut buckets: Vec<Vec<(Vertex, Vertex)>> =
                            (0..p).map(|_| Vec::new()).collect();
                        for &(u, v) in part {
                            assert!(
                                (u as usize) < n && (v as usize) < n,
                                "edge ({u},{v}) out of range n={n}"
                            );
                            buckets[machine_of(u.min(v) as u64, p)].push((u, v));
                        }
                        buckets
                    }
                })
                .collect(),
        );
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = (0..p).map(|_| Vec::new()).collect();
        for part in parts {
            for (dst, src) in buckets.iter_mut().zip(part) {
                dst.extend(src);
            }
        }
        finish_shards(n, buckets, cached_vertex_counts, &policy)
    }

    /// Shard a flat (already normalized) [`Graph`] — the ingest step.
    pub fn from_graph(g: &Graph, p: usize) -> Self {
        Self::from_graph_with(g, p, SpillPolicy::unbounded())
    }

    /// [`from_graph`](Self::from_graph) under a residency policy.
    pub fn from_graph_with(g: &Graph, p: usize, policy: SpillPolicy) -> Self {
        Self::from_edges_with(g.num_vertices(), p, g.edges().to_vec(), policy)
    }

    /// Assemble from per-shard buckets produced by shard-aligned workers
    /// (the coordinator pipeline: worker `s` only ever receives edges with
    /// `machine_of(min endpoint) == s`).  Each bucket is normalized in
    /// place into its shard — no flat concatenation, no resharding.
    pub fn from_shard_buckets(n: usize, buckets: Vec<Vec<(Vertex, Vertex)>>) -> Self {
        Self::from_shard_buckets_with(n, buckets, SpillPolicy::unbounded())
    }

    /// [`from_shard_buckets`](Self::from_shard_buckets) under a residency
    /// policy: over budget, each finalized bucket is written to its own
    /// shard file and dropped (the buckets themselves arrive in RAM from
    /// the workers; it is derived generations that stream).
    pub fn from_shard_buckets_with(
        n: usize,
        buckets: Vec<Vec<(Vertex, Vertex)>>,
        policy: SpillPolicy,
    ) -> Self {
        assert!(!buckets.is_empty(), "need at least one shard");
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        finish_shards(n, buckets, None, &policy)
            .unwrap_or_else(|e| panic!("shard spill failed during bucket assembly: {e}"))
    }

    pub fn num_vertices(&self) -> usize {
        self.n
    }

    pub fn num_shards(&self) -> usize {
        self.store.as_store().num_shards()
    }

    pub fn num_edges(&self) -> usize {
        (0..self.num_shards())
            .map(|s| self.store.as_store().stats(s).len as usize)
            .sum()
    }

    /// Resident bytes the edge set would cost ([`spill::EDGE_BYTES`] per
    /// edge) — the quantity the policy budget bounds.
    pub fn edge_bytes(&self) -> u64 {
        self.num_edges() as u64 * spill::EDGE_BYTES
    }

    /// Is the edge data currently on disk?
    pub fn is_spilled(&self) -> bool {
        self.store.as_store().is_spilled()
    }

    /// The spill directory of a spilled graph (`None` when resident).
    pub fn spill_dir(&self) -> Option<&Path> {
        match &self.store {
            Store::Resident(_) => None,
            Store::Spilled(s) => Some(s.dir()),
        }
    }

    /// RAM-cached payload checksum of shard `s` (`None` when resident —
    /// resident comparisons are already in-memory).  `pub(crate)` so the
    /// multi-process transport can pin shipped shard files to the cached
    /// generation without re-reading them.
    pub(crate) fn shard_checksum(&self, s: usize) -> Option<u64> {
        match &self.store {
            Store::Resident(_) => None,
            Store::Spilled(sp) => Some(sp.shard_metas()[s].checksum),
        }
    }

    /// The graph's residency policy.
    pub fn policy(&self) -> &SpillPolicy {
        &self.policy
    }

    /// Cached statistics of shard `s` (never touches disk).
    pub fn shard_stats(&self, s: usize) -> &ShardStats {
        self.store.as_store().stats(s)
    }

    /// The edges of shard `s`: borrowed when resident, loaded + validated
    /// from the shard file when spilled.  On-disk faults (truncation,
    /// corruption, a spill directory deleted mid-run) surface as typed
    /// [`SpillError`]s.
    pub fn read_shard(&self, s: usize) -> Result<ShardData<'_>, SpillError> {
        self.store.as_store().read(s)
    }

    /// Infallible [`read_shard`](Self::read_shard) for the hot paths that
    /// cannot propagate errors (round message chunks, degree
    /// accumulation).  Fault-tolerant callers use `read_shard` /
    /// [`try_to_graph`](Self::try_to_graph) instead.
    pub fn shard_data(&self, s: usize) -> ShardData<'_> {
        self.read_shard(s)
            .unwrap_or_else(|e| panic!("shard {s} unreadable: {e}"))
    }

    /// All edges, shard-major (shard order, sorted within each shard).
    /// Spilled shards load lazily, one at a time.
    pub fn iter_edges(&self) -> impl Iterator<Item = (Vertex, Vertex)> + '_ {
        (0..self.num_shards()).flat_map(move |s| self.shard_data(s))
    }

    /// One lazily-loaded message chunk per shard for the sharded round
    /// entry points: `make(s, primary, edges)` runs on the worker that
    /// consumes shard `s` and builds its message iterator, so at most
    /// `min(threads, machines)` shards are resident during a round.
    /// Every chunk is the full shard, so `primary` is always `true`.
    pub fn msg_chunks<'g, M, I>(&'g self, make: M) -> Vec<ShardMsgChunk<'g, M>>
    where
        M: Fn(usize, bool, ShardDataIter<'g>) -> I + Clone,
        I: Iterator,
    {
        self.msg_chunks_split(1, make)
    }

    /// [`msg_chunks`](Self::msg_chunks) with each shard further split into
    /// up to `parts` contiguous row sub-ranges, so a round over few (or
    /// one) spilled shards still saturates every pool thread: a mapped
    /// shard hands each sub-chunk a borrowed cursor slice over the same
    /// image — no per-thread copy.  The split is planned purely from the
    /// RAM-cached shard stats ([`chunk_range`] over `stats.len`), so the
    /// chunk list — and therefore chunk order and every metric derived
    /// from it — is identical for resident and spilled backends and for
    /// any thread count.  Exactly the first sub-chunk of each shard has
    /// `primary == true`; callers chain per-shard extras (self messages)
    /// onto primary chunks only.
    pub fn msg_chunks_split<'g, M, I>(
        &'g self,
        parts: usize,
        make: M,
    ) -> Vec<ShardMsgChunk<'g, M>>
    where
        M: Fn(usize, bool, ShardDataIter<'g>) -> I + Clone,
        I: Iterator,
    {
        let mut chunks = Vec::new();
        for s in 0..self.num_shards() {
            let m = self.store.as_store().stats(s).len as usize;
            // never emit an empty non-primary chunk: a shard with fewer
            // rows than `parts` splits into at most one chunk per row
            let k = parts.clamp(1, m.max(1));
            for i in 0..k {
                let (lo, hi) = chunk_range(m, k, i);
                chunks.push(ShardMsgChunk {
                    g: self,
                    s,
                    lo,
                    hi,
                    primary: i == 0,
                    make: make.clone(),
                });
            }
        }
        chunks
    }

    /// Per-machine ownership histogram of the vertex id space.
    pub fn vertex_counts(&self) -> &[u64] {
        &self.vertex_counts
    }

    /// Process-unique generation id of this edge set (clones share it;
    /// every rewrite mints a new one).  The shuffle transport tracks
    /// which generation the worker processes have custody of by this id.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Flatten to the canonical [`Graph`] view (for the oracle, the dense
    /// backend boundary, and tests).  Bit-identical to `Graph::normalize`
    /// of the same edge multiset: shards are canonical and globally
    /// duplicate-free, so a global sort is all that remains.  This is the
    /// one operation that intentionally materializes the full edge set.
    pub fn try_to_graph(&self) -> Result<Graph, SpillError> {
        let mut edges: Vec<(Vertex, Vertex)> = Vec::with_capacity(self.num_edges());
        for s in 0..self.num_shards() {
            edges.extend(self.read_shard(s)?);
        }
        // no dedup needed: equal edges share a shard, and shards are deduped
        crate::util::radix::par_sort_edge_pairs(&mut edges, false);
        Ok(Graph::from_edges_unchecked(self.n, edges))
    }

    /// Infallible [`try_to_graph`](Self::try_to_graph).
    pub fn to_graph(&self) -> Graph {
        self.try_to_graph()
            .unwrap_or_else(|e| panic!("cannot flatten sharded graph: {e}"))
    }

    /// Re-decide residency under a new policy: spills a resident graph
    /// that exceeds the new budget, loads a spilled one back when it fits.
    pub fn try_with_policy(mut self, policy: SpillPolicy) -> Result<ShardedGraph, SpillError> {
        let want_spill = policy.should_spill(self.edge_bytes());
        let is_spilled = self.is_spilled();
        self.policy = policy;
        if !is_spilled && want_spill {
            let old = std::mem::replace(&mut self.store, Store::Resident(Resident::default()));
            let Store::Resident(r) = old else { unreachable!() };
            self.store = spill_finished(r.into_shards(), &self.policy)?;
        } else if is_spilled && !want_spill {
            let shards = {
                let Store::Spilled(sp) = &self.store else { unreachable!() };
                unspill_all(sp)?
            };
            self.store = Store::Resident(Resident::new(shards));
        }
        Ok(self)
    }

    /// Infallible [`try_with_policy`](Self::try_with_policy).
    pub fn with_policy(self, policy: SpillPolicy) -> ShardedGraph {
        self.try_with_policy(policy)
            .unwrap_or_else(|e| panic!("cannot re-back sharded graph: {e}"))
    }

    /// Persist the shards plus a checksummed manifest into `dir` (created
    /// if missing) so the graph survives the process: reload with
    /// [`open_spilled`](Self::open_spilled).  Shard files are written
    /// pool-parallel (one read-validate-write cycle per shard, chunked
    /// like every other multi-shard pass); the manifest goes last so a
    /// crash mid-persist leaves no valid manifest over partial files.
    pub fn persist_spilled<P: AsRef<Path>>(&self, dir: P) -> Result<(), SpillError> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).map_err(|e| SpillError::io(dir, "create dir", e))?;
        let p = self.num_shards();
        let t = pool::global().threads().clamp(1, p);
        let jobs: Vec<_> = (0..t)
            .map(|i| {
                let (a, b) = chunk_range(p, t, i);
                move || -> Result<Vec<spill::ManifestShard>, SpillError> {
                    (a..b)
                        .map(|s| {
                            let data = self.read_shard(s)?;
                            let len = data.len() as u64;
                            // write_shard_file streams a contiguous slice;
                            // a mapped source copies once here, off the
                            // hot round path
                            let edges = data.into_vec();
                            let path = dir.join(spill::shard_file_name(s));
                            let checksum =
                                spill::write_shard_file(&path, s as u32, p as u32, &edges)?;
                            Ok(spill::ManifestShard {
                                len,
                                checksum,
                                peer_counts: self.shard_stats(s).peer_counts.clone(),
                            })
                        })
                        .collect()
                }
            })
            .collect();
        let mut shards = Vec::with_capacity(p);
        for part in pool::global().run_jobs(jobs) {
            shards.extend(part?);
        }
        spill::write_manifest(
            &dir.join(spill::MANIFEST_NAME),
            &spill::Manifest {
                n: self.n as u64,
                p: p as u32,
                shards,
            },
        )
    }

    /// Reload a graph persisted by [`persist_spilled`](Self::persist_spilled)
    /// as a spilled-backend graph over the user-owned directory (not
    /// removed on drop).  The manifest and every shard file length are
    /// validated eagerly; payload checksums verify on each shard read.
    pub fn open_spilled<P: AsRef<Path>>(
        dir: P,
        policy: SpillPolicy,
    ) -> Result<ShardedGraph, SpillError> {
        let dir = dir.as_ref();
        let manifest_path = dir.join(spill::MANIFEST_NAME);
        let man = spill::read_manifest(&manifest_path)?;
        let p = man.p as usize;
        // manifest-supplied dimensions are untrusted: a p of 0 would make
        // machine_of divide by zero and an oversized n violates the u32
        // vertex-id bound every constructor asserts — both must be typed
        // errors, not panics
        if p == 0 {
            return Err(SpillError::Corrupt {
                path: manifest_path,
                detail: "manifest declares zero shards".into(),
            });
        }
        if man.n > u32::MAX as u64 {
            return Err(SpillError::Corrupt {
                path: manifest_path,
                detail: format!("manifest declares n={} (> u32::MAX vertex ids)", man.n),
            });
        }
        let n = man.n as usize;
        let mut metas = Vec::with_capacity(p);
        for (s, ms) in man.shards.iter().enumerate() {
            let path = dir.join(spill::shard_file_name(s));
            spill::validate_shard_file_len(&path, ms.len)?;
            if ms.peer_counts.len() != p {
                return Err(SpillError::Corrupt {
                    path,
                    detail: format!(
                        "manifest shard {s} has {} peer counts, expected {p}",
                        ms.peer_counts.len()
                    ),
                });
            }
            metas.push(SpilledShard::new(
                path,
                ShardStats {
                    len: ms.len,
                    peer_counts: ms.peer_counts.clone(),
                },
                ms.checksum,
            ));
        }
        Ok(ShardedGraph {
            n,
            store: Store::Spilled(Spilled::from_parts(
                Arc::new(SpillDir::adopt(dir.to_path_buf())),
                metas,
            )),
            vertex_counts: vertex_counts(n, p),
            policy,
            gen: next_gen(),
        })
    }

    /// Per-vertex degree via per-worker partial counts merged in fixed
    /// order (normalized-graph semantics, identical to `Graph::degrees`).
    /// Spilled shards load one per worker at a time.
    pub fn degrees(&self) -> Vec<u32> {
        let n = self.n;
        let p = self.num_shards();
        let t = pool::global().threads().clamp(1, p);
        if t <= 1 {
            let mut deg = vec![0u32; n];
            for (u, v) in self.iter_edges() {
                deg[u as usize] += 1;
                deg[v as usize] += 1;
            }
            return deg;
        }
        let parts = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(p, t, i);
                    move || {
                        let mut deg = vec![0u32; n];
                        for s in a..b {
                            let data = self.shard_data(s);
                            for (u, v) in data.iter() {
                                deg[u as usize] += 1;
                                deg[v as usize] += 1;
                            }
                        }
                        deg
                    }
                })
                .collect(),
        );
        let mut deg = vec![0u32; n];
        for part in parts {
            for (d, c) in deg.iter_mut().zip(&part) {
                *d += c;
            }
        }
        deg
    }

    /// Rewrite every edge through `f` and re-bucket the results into their
    /// new owner shards (the graph-layer half of the contraction rounds).
    /// `f` returns rewritten endpoints or `None` to drop the edge.
    ///
    /// Resident source: one in-RAM pass (rewrite + re-bucket fused), then
    /// [`finish_shards`] — which spills the *output* if it exceeds the
    /// budget.  Spilled source: [`rewrite_streamed`](Self::rewrite_streamed)
    /// — load → rewrite → spill per shard, never materializing the full
    /// edge set.
    fn rewrite_into<F>(&self, new_n: usize, new_p: usize, f: F) -> ShardedGraph
    where
        F: Fn(Vertex, Vertex) -> Option<(Vertex, Vertex)> + Sync,
    {
        self.try_rewrite_into(new_n, new_p, f)
            .unwrap_or_else(|e| panic!("shard spill failed during rewrite: {e}"))
    }

    fn try_rewrite_into<F>(
        &self,
        new_n: usize,
        new_p: usize,
        f: F,
    ) -> Result<ShardedGraph, SpillError>
    where
        F: Fn(Vertex, Vertex) -> Option<(Vertex, Vertex)> + Sync,
    {
        // vertex_counts is a pure function of (n, p): reuse the cache when
        // the rewrite keeps the vertex universe and shard count.
        let cached = if new_n == self.n && new_p == self.num_shards() {
            Some(self.vertex_counts.clone())
        } else {
            None
        };
        if self.is_spilled() {
            return self.rewrite_streamed(new_n, new_p, f, cached);
        }
        let p = self.num_shards();
        let t = pool::global().threads().clamp(1, p);
        let f = &f;
        let parts: Vec<Vec<Vec<(Vertex, Vertex)>>> = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(p, t, i);
                    move || {
                        let mut buckets: Vec<Vec<(Vertex, Vertex)>> =
                            (0..new_p).map(|_| Vec::new()).collect();
                        for s in a..b {
                            let data = self.shard_data(s);
                            for (u, v) in data.iter() {
                                if let Some((x, y)) = f(u, v) {
                                    let (x, y) = if x <= y { (x, y) } else { (y, x) };
                                    if x != y {
                                        buckets[machine_of(x as u64, new_p)].push((x, y));
                                    }
                                }
                            }
                        }
                        buckets
                    }
                })
                .collect(),
        );
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = (0..new_p).map(|_| Vec::new()).collect();
        for part in parts {
            for (dst, src) in buckets.iter_mut().zip(part) {
                dst.extend(src);
            }
        }
        finish_shards(new_n, buckets, cached, &self.policy)
    }

    /// The out-of-core rewrite: workers process source shards one at a
    /// time (load → rewrite → append), streaming rewritten edges into one
    /// unframed staging file per destination shard; a second shard-parallel
    /// pass finalizes each destination (sort + dedup + stats) and writes
    /// its checksummed shard file — or keeps the result resident if the
    /// rewritten set now fits the budget.
    ///
    /// Each per-destination buffer is sorted + deduped **before** staging,
    /// so a source shard contributes at most its distinct rewritten edges
    /// to each destination and a staged bucket is bounded by
    /// `sources × distinct(dest)` — a heavily-merging contraction (many
    /// inputs collapsing onto few supernode edges) cannot balloon one
    /// staging file to O(m) duplicates.  Peak RAM per worker is therefore
    /// O(largest input shard + largest staged destination), never O(m).
    /// The output is bit-identical to the resident path because the final
    /// per-shard sort + dedup canonicalizes away both the staging append
    /// order and the early dedup.
    fn rewrite_streamed<F>(
        &self,
        new_n: usize,
        new_p: usize,
        f: F,
        cached_vertex_counts: Option<Vec<u64>>,
    ) -> Result<ShardedGraph, SpillError>
    where
        F: Fn(Vertex, Vertex) -> Option<(Vertex, Vertex)> + Sync,
    {
        use std::io::BufWriter;

        let p = self.num_shards();
        let root = self.policy.root.as_deref();
        let staging = SpillDir::create_temp(root)?;
        let stage_path = |d: usize| staging.path().join(format!("stage-{d:05}.raw"));
        let appenders: Vec<Mutex<BufWriter<std::fs::File>>> = (0..new_p)
            .map(|d| {
                let path = stage_path(d);
                std::fs::File::create(&path)
                    .map(|f| Mutex::new(BufWriter::new(f)))
                    .map_err(|e| SpillError::io(&path, "create", e))
            })
            .collect::<Result<_, _>>()?;

        // phase A: load → rewrite → append, one source shard per worker
        let t = pool::global().threads().clamp(1, p);
        let f = &f;
        let appenders_ref = &appenders;
        let stage_path = &stage_path;
        let results: Vec<Result<(), SpillError>> = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(p, t, i);
                    move || -> Result<(), SpillError> {
                        for s in a..b {
                            let data = self.read_shard(s)?;
                            let mut bufs: Vec<Vec<(Vertex, Vertex)>> =
                                (0..new_p).map(|_| Vec::new()).collect();
                            for (u, v) in data.iter() {
                                if let Some((x, y)) = f(u, v) {
                                    let (x, y) = if x <= y { (x, y) } else { (y, x) };
                                    if x != y {
                                        bufs[machine_of(x as u64, new_p)].push((x, y));
                                    }
                                }
                            }
                            drop(data);
                            for (d, mut buf) in bufs.into_iter().enumerate() {
                                if buf.is_empty() {
                                    continue;
                                }
                                // early dedup: bounds staged duplicates under
                                // heavy merges (see method docs); harmless to
                                // the result — phase B sorts + dedups again
                                buf.sort_unstable();
                                buf.dedup();
                                let mut w = appenders_ref[d]
                                    .lock()
                                    .expect("staging writer poisoned");
                                crate::graph::io::write_pairs(&mut *w, &buf)
                                    .map_err(|e| SpillError::io(&stage_path(d), "append", e))?;
                            }
                        }
                        Ok(())
                    }
                })
                .collect(),
        );
        for r in results {
            r?;
        }

        // flush the staged buckets (phase B streams them back per shard)
        let mut staged: Vec<(std::path::PathBuf, u64)> = Vec::with_capacity(new_p);
        let mut staged_bytes = 0u64;
        for (d, m) in appenders.into_iter().enumerate() {
            let path = stage_path(d);
            let w = m.into_inner().expect("staging writer poisoned");
            let file = w
                .into_inner() // flushes
                .map_err(|e| SpillError::io(&path, "flush", e.into_error()))?;
            let len = file
                .metadata()
                .map_err(|e| SpillError::io(&path, "stat", e))?
                .len();
            staged_bytes += len;
            staged.push((path, len));
        }

        // phase B: finalize each destination (sort + dedup + stats).  The
        // residency decision is on *finalized* (post-dedup) bytes — the
        // same quantity the resident path's finish_shards uses, so both
        // paths always pick the same backend.  Staged bytes are an upper
        // bound on finalized bytes, so a staged total already under the
        // budget proves the result is resident and skips the shard files
        // entirely (the common shrinking-contraction case); only an
        // over-budget staging goes through files, with a cheap reload in
        // the rare between case.
        let staged_ref = &staged;
        let finalize = |d: usize| -> Result<EdgeShard, SpillError> {
            let (path, len) = &staged_ref[d];
            let mut edges = spill::read_raw_pairs(path, *len)?;
            edges.sort_unstable();
            edges.dedup();
            Ok(EdgeShard::new_canonical(edges, new_p, d))
        };
        let finalize = &finalize;
        let t2 = pool::global().threads().clamp(1, new_p);
        let store = if !self.policy.should_spill(staged_bytes) {
            let jobs: Vec<_> = (0..t2)
                .map(|i| {
                    let (a, b) = chunk_range(new_p, t2, i);
                    move || -> Result<Vec<EdgeShard>, SpillError> {
                        (a..b).map(finalize).collect()
                    }
                })
                .collect();
            let mut shards = Vec::with_capacity(new_p);
            for part in pool::global().run_jobs(jobs) {
                shards.extend(part?);
            }
            Store::Resident(Resident::new(shards))
        } else {
            let dir = Arc::new(SpillDir::create_temp(root)?);
            let jobs: Vec<_> = (0..t2)
                .map(|i| {
                    let (a, b) = chunk_range(new_p, t2, i);
                    let dir = Arc::clone(&dir);
                    move || -> Result<Vec<SpilledShard>, SpillError> {
                        (a..b)
                            .map(|d| spill::spill_shard(&dir, d, new_p, &finalize(d)?))
                            .collect()
                    }
                })
                .collect();
            let mut metas = Vec::with_capacity(new_p);
            for part in pool::global().run_jobs(jobs) {
                metas.extend(part?);
            }
            let final_bytes: u64 = metas
                .iter()
                .map(|m| m.stats.len * spill::EDGE_BYTES)
                .sum();
            if self.policy.should_spill(final_bytes) {
                Store::Spilled(Spilled::from_parts(dir, metas))
            } else {
                // dedup shrank it under the budget after all: reload with
                // the stats we just computed (no re-hash)
                let spilled = Spilled::from_parts(dir, metas);
                let shards = unspill_all(&spilled)?;
                Store::Resident(Resident::new(shards))
                // `spilled` (the last Arc) drops here and removes its files
            }
        };
        drop(staging); // removes the stage files

        let vertex_counts =
            cached_vertex_counts.unwrap_or_else(|| vertex_counts(new_n, new_p));
        Ok(ShardedGraph {
            n: new_n,
            store,
            vertex_counts,
            policy: self.policy.clone(),
            gen: next_gen(),
        })
    }

    /// Contraction G/r of §2: vertices with equal labels merge; self-loops
    /// and duplicates vanish in the shard-local normalize.  Returns the
    /// contracted graph plus the old-vertex -> new-node compaction map
    /// (bit-identical to [`Graph::contract`] via the shared
    /// [`compact_labels`]).
    pub fn contract(&self, labels: &[Vertex]) -> (ShardedGraph, Vec<Vertex>) {
        assert_eq!(labels.len(), self.n, "labels len != n");
        let (compact, count) = compact_labels(labels, self.n);
        let compact_ref = &compact;
        let contracted = self.rewrite_into(count, self.num_shards(), |u, v| {
            Some((compact_ref[u as usize], compact_ref[v as usize]))
        });
        (contracted, compact)
    }

    /// Drop isolated vertices, compacting ids (§6).  Returns the pruned
    /// graph and the old-id -> Some(new-id) map (None for dropped
    /// vertices), matching `Graph::prune_isolated`.
    pub fn prune_isolated(&self) -> (ShardedGraph, Vec<Option<Vertex>>) {
        let deg = self.degrees();
        let mut map = vec![None; self.n];
        let mut next = 0u32;
        for v in 0..self.n {
            if deg[v] > 0 {
                map[v] = Some(next);
                next += 1;
            }
        }
        let map_ref = &map;
        let pruned = self.rewrite_into(next as usize, self.num_shards(), |u, v| {
            Some((map_ref[u as usize].unwrap(), map_ref[v as usize].unwrap()))
        });
        (pruned, map)
    }

    /// Re-partition to a different shard count (e.g. pipeline workers ->
    /// simulator machines).  Shard-to-shard: every input shard buckets its
    /// edges by the new ownership directly — the edge list is never
    /// flattened into one vector.
    pub fn reshard(&self, p: usize) -> ShardedGraph {
        let p = p.max(1);
        if p == self.num_shards() {
            return self.clone();
        }
        self.rewrite_into(self.n, p, |u, v| Some((u, v)))
    }

    /// Exact accounting of one neighborhood-hop round: every edge sends a
    /// fixed-size message to both endpoint keys (the left one lands on the
    /// owner shard by the invariant; the right one on the cached peer
    /// histogram), plus one self message per vertex when `include_self`.
    /// `msg_size` is the full wire size of one message (8-byte key +
    /// value).  A pure function of shard statistics — no `machine_of`
    /// per message, and **no disk access** even when spilled.
    pub fn hop_charge(&self, msg_size: u64, include_self: bool) -> ShardRound {
        let p = self.num_shards();
        let m = self.num_edges() as u64;
        let mut machine_bytes = vec![0u64; p];
        for s in 0..p {
            let stats = self.store.as_store().stats(s);
            machine_bytes[s] += msg_size * stats.len;
            for (mb, &c) in machine_bytes.iter_mut().zip(&stats.peer_counts) {
                *mb += msg_size * c;
            }
        }
        let mut messages = 2 * m;
        if include_self {
            messages += self.n as u64;
            for (mb, &c) in machine_bytes.iter_mut().zip(&self.vertex_counts) {
                *mb += msg_size * c;
            }
        }
        ShardRound {
            messages,
            bytes: messages * msg_size,
            machine_bytes,
        }
    }

    /// Exact accounting of the two contraction rounds of Lemma 3.1
    /// (12-byte messages: 8-byte key + one endpoint).  Round 1 keys every
    /// edge by its left endpoint — the owner shard itself; round 2 by its
    /// right endpoint — the cached peer histogram.  No disk access.
    pub fn contract_charges(&self) -> (ShardRound, ShardRound) {
        let p = self.num_shards();
        let m = self.num_edges() as u64;
        let mut left = vec![0u64; p];
        let mut right = vec![0u64; p];
        for s in 0..p {
            let stats = self.store.as_store().stats(s);
            left[s] = 12 * stats.len;
            for (r, &c) in right.iter_mut().zip(&stats.peer_counts) {
                *r += 12 * c;
            }
        }
        (
            ShardRound {
                messages: m,
                bytes: 12 * m,
                machine_bytes: left,
            },
            ShardRound {
                messages: m,
                bytes: 12 * m,
                machine_bytes: right,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_raw(n: u64, m: usize, seed: u64) -> Vec<(Vertex, Vertex)> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| (rng.gen_range(n) as Vertex, rng.gen_range(n) as Vertex))
            .collect()
    }

    /// Both backends for the same input: resident, and spilled under a
    /// zero-byte budget (everything with edges goes to disk).
    fn both_backends(n: usize, p: usize, raw: Vec<(Vertex, Vertex)>) -> [ShardedGraph; 2] {
        [
            ShardedGraph::from_edges(n, p, raw.clone()),
            ShardedGraph::from_edges_with(n, p, raw, SpillPolicy::budget(0)),
        ]
    }

    #[test]
    fn from_edges_matches_monolithic_normalize() {
        for p in [1usize, 4, 16] {
            for (n, m, seed) in [(50u64, 300usize, 1u64), (500, 8000, 2), (40, 0, 3)] {
                let raw = random_raw(n, m, seed);
                let flat = Graph::from_edges(n as usize, raw.clone());
                for g in both_backends(n as usize, p, raw) {
                    assert_eq!(g.to_graph(), flat, "p={p} n={n} m={m}");
                    assert_eq!(g.num_edges(), flat.num_edges());
                    assert_eq!(g.num_shards(), p);
                }
            }
        }
    }

    #[test]
    fn shard_ownership_invariant_holds() {
        let raw = random_raw(200, 3000, 7);
        for g in both_backends(200, 8, raw) {
            for s in 0..g.num_shards() {
                let data = g.read_shard(s).unwrap();
                let mut prev: Option<(Vertex, Vertex)> = None;
                let mut peers = vec![0u64; 8];
                for (u, v) in data.iter() {
                    assert!(u < v, "non-canonical ({u},{v})");
                    assert_eq!(machine_of(u as u64, 8), s, "wrong owner for ({u},{v})");
                    peers[machine_of(v as u64, 8)] += 1;
                    if let Some(pv) = prev {
                        assert!(pv < (u, v), "not sorted/deduped");
                    }
                    prev = Some((u, v));
                }
                assert_eq!(peers, g.shard_stats(s).peer_counts, "peer histogram stale");
            }
            let total: u64 = g.vertex_counts().iter().sum();
            assert_eq!(total, 200);
        }
    }

    #[test]
    fn contract_matches_graph_contract() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(120, 900, 11);
            let flat = Graph::from_edges(120, raw.clone());
            let labels: Vec<Vertex> = (0..120u32).map(|v| v % 37).collect();
            let (cf, mf) = flat.contract(&labels);
            for sharded in both_backends(120, p, raw.clone()) {
                let (cs, ms) = sharded.contract(&labels);
                assert_eq!(ms, mf, "p={p}: compaction maps differ");
                assert_eq!(cs.to_graph(), cf, "p={p}: contracted graphs differ");
            }
        }
    }

    #[test]
    fn contract_sparse_labels_match_fallback() {
        let raw = vec![(0u32, 1u32), (1, 2)];
        let flat = Graph::from_edges(3, raw.clone());
        let sharded = ShardedGraph::from_edges(3, 4, raw);
        let labels = vec![1_000_000u32, 5, 5];
        let (cf, mf) = flat.contract(&labels);
        let (cs, ms) = sharded.contract(&labels);
        assert_eq!(ms, mf);
        assert_eq!(cs.to_graph(), cf);
    }

    #[test]
    fn degrees_and_prune_match_monolithic() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(80, 120, 21);
            let flat = Graph::from_edges(80, raw.clone());
            let (pf, mapf) = flat.prune_isolated();
            for sharded in both_backends(80, p, raw.clone()) {
                assert_eq!(sharded.degrees(), flat.degrees(), "p={p}");
                let (ps, maps) = sharded.prune_isolated();
                assert_eq!(maps, mapf, "p={p}");
                assert_eq!(ps.to_graph(), pf, "p={p}");
            }
        }
    }

    #[test]
    fn hop_charge_matches_per_message_accounting() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(150, 2000, 31);
            for g in both_backends(150, p, raw.clone()) {
                for (msg_size, include_self) in [(12u64, true), (12, false), (16, true)] {
                    let charge = g.hop_charge(msg_size, include_self);
                    // brute force over the actual message multiset
                    let mut mb = vec![0u64; p];
                    let mut msgs = 0u64;
                    for (u, v) in g.iter_edges() {
                        mb[machine_of(u as u64, p)] += msg_size;
                        mb[machine_of(v as u64, p)] += msg_size;
                        msgs += 2;
                    }
                    if include_self {
                        for v in 0..g.num_vertices() {
                            mb[machine_of(v as u64, p)] += msg_size;
                        }
                        msgs += g.num_vertices() as u64;
                    }
                    assert_eq!(charge.messages, msgs, "p={p}");
                    assert_eq!(charge.bytes, msgs * msg_size, "p={p}");
                    assert_eq!(charge.machine_bytes, mb, "p={p} self={include_self}");
                }
            }
        }
    }

    #[test]
    fn contract_charges_match_per_message_accounting() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(100, 1500, 41);
            for g in both_backends(100, p, raw.clone()) {
                let (left, right) = g.contract_charges();
                let mut mb_left = vec![0u64; p];
                let mut mb_right = vec![0u64; p];
                for (u, v) in g.iter_edges() {
                    mb_left[machine_of(u as u64, p)] += 12;
                    mb_right[machine_of(v as u64, p)] += 12;
                }
                let m = g.num_edges() as u64;
                assert_eq!((left.messages, left.bytes), (m, 12 * m));
                assert_eq!((right.messages, right.bytes), (m, 12 * m));
                assert_eq!(left.machine_bytes, mb_left, "p={p}");
                assert_eq!(right.machine_bytes, mb_right, "p={p}");
            }
        }
    }

    #[test]
    fn from_edges_like_matches_direct_build() {
        let a = ShardedGraph::from_edges(70, 4, random_raw(70, 300, 71));
        let b = a.from_edges_like(random_raw(70, 200, 72));
        let direct = ShardedGraph::from_edges(70, 4, random_raw(70, 200, 72));
        assert_eq!(b, direct);
        assert_eq!(b.vertex_counts(), a.vertex_counts());
    }

    #[test]
    fn reshard_preserves_the_graph() {
        let raw = random_raw(90, 700, 51);
        for g4 in both_backends(90, 4, raw.clone()) {
            let g16 = g4.reshard(16);
            let g1 = g16.reshard(1);
            assert_eq!(g16.num_shards(), 16);
            assert_eq!(g16.to_graph(), g4.to_graph());
            assert_eq!(g1.to_graph(), g4.to_graph());
            assert_eq!(g4.reshard(4), g4); // same count: clone
        }
    }

    #[test]
    fn from_shard_buckets_accepts_worker_output() {
        // pipeline shape: raw (possibly reversed) edges, bucketed by the
        // min-endpoint hash at the generator
        let raw = random_raw(60, 400, 61);
        let p = 3;
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = vec![Vec::new(); p];
        for &(u, v) in &raw {
            if u != v {
                buckets[machine_of(u.min(v) as u64, p)].push((u, v));
            }
        }
        let flat = Graph::from_edges(60, raw);
        let g = ShardedGraph::from_shard_buckets(60, buckets.clone());
        assert_eq!(g.to_graph(), flat);
        let spilled =
            ShardedGraph::from_shard_buckets_with(60, buckets, SpillPolicy::budget(0));
        assert!(spilled.is_spilled());
        assert_eq!(spilled.to_graph(), flat);
    }

    #[test]
    fn empty_and_single_shard() {
        let g = ShardedGraph::empty(5, 4);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.degrees(), vec![0; 5]);
        let charge = g.hop_charge(12, true);
        assert_eq!(charge.messages, 5);
        let g1 = ShardedGraph::from_edges(3, 1, vec![(0, 1), (1, 0), (2, 2)]);
        assert_eq!(g1.num_shards(), 1);
        assert_eq!(g1.to_graph().edges(), &[(0, 1)]);
    }

    #[test]
    fn spilled_backend_is_chosen_by_budget() {
        let raw = random_raw(100, 800, 81);
        let resident = ShardedGraph::from_edges_with(
            100,
            4,
            raw.clone(),
            SpillPolicy::budget(u64::MAX),
        );
        assert!(!resident.is_spilled());
        let spilled = ShardedGraph::from_edges_with(100, 4, raw, SpillPolicy::budget(16));
        assert!(spilled.is_spilled());
        assert!(spilled.spill_dir().unwrap().exists());
        assert_eq!(resident, spilled, "content equality across backends");
    }

    #[test]
    fn spilled_contraction_unspills_when_it_fits() {
        // budget below the input but above the contracted output: the
        // rewrite's load → rewrite → spill loop lands back in RAM
        let raw = random_raw(200, 3000, 91);
        let g = ShardedGraph::from_edges_with(200, 4, raw, SpillPolicy::budget(64));
        assert!(g.is_spilled());
        let labels: Vec<Vertex> = vec![0; 200]; // everything merges
        let (c, _) = g.contract(&labels);
        assert_eq!(c.num_edges(), 0);
        assert!(!c.is_spilled(), "empty contraction should fit any budget");
    }

    #[test]
    fn with_policy_roundtrips_backends() {
        let raw = random_raw(90, 600, 101);
        let g = ShardedGraph::from_edges(90, 4, raw);
        let flat = g.to_graph();
        let spilled = g.clone().with_policy(SpillPolicy::budget(8));
        assert!(spilled.is_spilled());
        assert_eq!(spilled.to_graph(), flat);
        let back = spilled.with_policy(SpillPolicy::unbounded());
        assert!(!back.is_spilled());
        assert_eq!(back, g);
    }

    #[test]
    fn spill_files_are_cleaned_up_on_drop() {
        let raw = random_raw(80, 500, 111);
        let g = ShardedGraph::from_edges_with(80, 4, raw, SpillPolicy::budget(0));
        let dir = g.spill_dir().unwrap().to_path_buf();
        assert!(dir.exists());
        let clone = g.clone();
        drop(g);
        assert!(dir.exists(), "clone still shares the generation dir");
        drop(clone);
        assert!(!dir.exists(), "last drop removes the spill generation");
    }

    #[test]
    fn persist_and_reload_roundtrip() {
        let raw = random_raw(120, 900, 121);
        let g = ShardedGraph::from_edges_with(120, 4, raw, SpillPolicy::budget(0));
        let dir = std::env::temp_dir().join(format!(
            "lcc-sharded-persist-{}",
            std::process::id()
        ));
        g.persist_spilled(&dir).unwrap();
        let h = ShardedGraph::open_spilled(&dir, SpillPolicy::budget(0)).unwrap();
        assert_eq!(h, g);
        assert_eq!(h.to_graph(), g.to_graph());
        drop(h);
        assert!(dir.exists(), "user-owned dir survives the graph");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_edges_rejects_out_of_range() {
        // single worker job: panic message survives (inline execution)
        let _ = ShardedGraph::from_edges(2, 1, vec![(0, 5)]);
    }
}
