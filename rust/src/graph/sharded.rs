//! The sharded edge store: the machine partition as the system's
//! **resident** graph representation.
//!
//! The paper's contractions scale to trillions of edges because no machine
//! ever holds the full edge list.  This module makes that layout native:
//! a [`ShardedGraph`] owns its edges as one [`EdgeShard`] per simulated
//! machine, where the canonical edge `(u, v)` (`u < v`) lives on machine
//! `machine_of(u)` — the same stable hash the MPC shuffle rounds use.
//!
//! **Shard-ownership invariant.**  For every shard `s` and every edge
//! `(u, v)` stored there: `u < v` and `machine_of(u, p) == s`, the shard's
//! edge list is sorted and duplicate-free, and two cached histograms are
//! maintained alongside the edges:
//!
//! * `peer_counts[j]` — edges of the shard whose *right* endpoint is owned
//!   by machine `j` (the destination of the second message of every hop
//!   and of the second contraction round);
//! * `vertex_counts[j]` — vertices `v ∈ 0..n` with `machine_of(v) == j`
//!   (the destinations of the per-vertex self messages).
//!
//! Because the partition function is the message-key hash, the exact
//! per-machine byte loads of every hop and contraction round are **pure
//! functions of these shard statistics** ([`ShardedGraph::hop_charge`],
//! [`ShardedGraph::contract_charges`]) — the round engine never recomputes
//! `machine_of` per message.  Mutating operations (`contract`,
//! `prune_isolated`, [`ShardedGraph::from_edges`]) re-bucket rewritten
//! edges into their new owner shards in the same pass that rewrites them,
//! running shard-parallel on the worker pool.
//!
//! [`Graph`] remains the flat ingest/oracle format; [`ShardedGraph::to_graph`]
//! is the thin conversion back (bit-identical to a monolithic
//! `Graph::normalize` of the same edge multiset — enforced by
//! `rust/tests/sharded_representation.rs`).

use super::edgelist::{compact_labels, Graph, Vertex};
use crate::mpc::pool::{self, chunk_range};
use crate::mpc::simulator::{machine_of, ShardRound};

/// One machine's slice of the edge list plus its cached load histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeShard {
    /// Canonical `(min, max)` edges owned by this shard: sorted, deduped,
    /// no self-loops, `machine_of(min) == shard index`.
    edges: Vec<(Vertex, Vertex)>,
    /// `peer_counts[j]` = edges here whose max endpoint machine is `j`.
    peer_counts: Vec<u64>,
}

impl EdgeShard {
    pub fn edges(&self) -> &[(Vertex, Vertex)] {
        &self.edges
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Per-machine ownership histogram of this shard's right endpoints.
    pub fn peer_counts(&self) -> &[u64] {
        &self.peer_counts
    }
}

/// An undirected graph resident as `machines` edge shards (see module docs
/// for the ownership invariant).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedGraph {
    n: usize,
    shards: Vec<EdgeShard>,
    /// `vertex_counts[j]` = vertices of `0..n` owned by machine `j`.
    vertex_counts: Vec<u64>,
}

/// `machine_of` histogram of the vertex ids `0..n` (self-message loads),
/// computed in parallel chunks merged in fixed order.
fn vertex_counts(n: usize, p: usize) -> Vec<u64> {
    let t = pool::global()
        .threads()
        .clamp(1, n.div_ceil(1 << 14).max(1));
    if t <= 1 {
        let mut h = vec![0u64; p];
        for v in 0..n {
            h[machine_of(v as u64, p)] += 1;
        }
        return h;
    }
    let parts = pool::global().run_jobs(
        (0..t)
            .map(|i| {
                let (a, b) = chunk_range(n, t, i);
                move || {
                    let mut h = vec![0u64; p];
                    for v in a..b {
                        h[machine_of(v as u64, p)] += 1;
                    }
                    h
                }
            })
            .collect(),
    );
    let mut h = vec![0u64; p];
    for part in parts {
        for (a, b) in h.iter_mut().zip(&part) {
            *a += b;
        }
    }
    h
}

/// Finalize per-shard buckets into a canonical [`ShardedGraph`]:
/// canonicalize each edge to `(min, max)`, drop self-loops, sort + dedup
/// within the shard (equal edges always share a shard, so per-shard dedup
/// *is* global dedup), and compute the cached peer histogram — one pass,
/// shard-parallel on the worker pool.  Bucket `s` must only contain edges
/// it owns (`machine_of(min endpoint) == s`; enforced in debug builds).
/// `cached_vertex_counts` may carry the histogram of a previous graph
/// over the **same** `(n, p)` — it is a pure function of those two, so
/// per-round rebuilds skip the O(n) re-hash.
fn finish_shards(
    n: usize,
    buckets: Vec<Vec<(Vertex, Vertex)>>,
    cached_vertex_counts: Option<Vec<u64>>,
) -> ShardedGraph {
    let p = buckets.len();
    let t = pool::global().threads().clamp(1, p);
    let mut it = buckets.into_iter().enumerate();
    let mut jobs = Vec::with_capacity(t);
    for i in 0..t {
        let (a, b) = chunk_range(p, t, i);
        let group: Vec<(usize, Vec<(Vertex, Vertex)>)> = it.by_ref().take(b - a).collect();
        jobs.push(move || {
            group
                .into_iter()
                .map(|(s, mut edges)| {
                    for e in edges.iter_mut() {
                        if e.0 > e.1 {
                            *e = (e.1, e.0);
                        }
                    }
                    edges.retain(|e| e.0 != e.1);
                    edges.sort_unstable();
                    edges.dedup();
                    let mut peer_counts = vec![0u64; p];
                    for &(u, v) in &edges {
                        debug_assert_eq!(
                            machine_of(u as u64, p),
                            s,
                            "edge ({u},{v}) stored on the wrong shard"
                        );
                        peer_counts[machine_of(v as u64, p)] += 1;
                    }
                    let _ = s;
                    EdgeShard { edges, peer_counts }
                })
                .collect::<Vec<EdgeShard>>()
        });
    }
    let shards: Vec<EdgeShard> = pool::global()
        .run_jobs(jobs)
        .into_iter()
        .flatten()
        .collect();
    let vertex_counts = match cached_vertex_counts {
        Some(counts) => {
            debug_assert_eq!(counts.len(), p);
            debug_assert_eq!(counts.iter().sum::<u64>(), n as u64);
            counts
        }
        None => vertex_counts(n, p),
    };
    ShardedGraph {
        n,
        shards,
        vertex_counts,
    }
}

impl ShardedGraph {
    /// Empty graph on `n` vertices over `p` shards (`p` is clamped to 1).
    pub fn empty(n: usize, p: usize) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        let p = p.max(1);
        ShardedGraph {
            n,
            shards: (0..p)
                .map(|_| EdgeShard {
                    edges: Vec::new(),
                    peer_counts: vec![0; p],
                })
                .collect(),
            vertex_counts: vertex_counts(n, p),
        }
    }

    /// Build from raw edges: bucket each edge to its owner shard
    /// (`machine_of(min endpoint)`) in parallel chunks, then normalize
    /// shard-locally (canonical order, per-shard sort + dedup, no loops) —
    /// no global sort anywhere.
    pub fn from_edges(n: usize, p: usize, edges: Vec<(Vertex, Vertex)>) -> Self {
        Self::from_edges_cached(n, p, edges, None)
    }

    /// [`from_edges`](Self::from_edges) over the **same vertex universe
    /// and shard count** as `self`, reusing its cached vertex ownership
    /// histogram — the per-round rebuild path (Cracker's rewire,
    /// Two-Phase's star rounds) skips n `machine_of` hashes each round.
    pub fn from_edges_like(&self, edges: Vec<(Vertex, Vertex)>) -> Self {
        Self::from_edges_cached(
            self.n,
            self.shards.len(),
            edges,
            Some(self.vertex_counts.clone()),
        )
    }

    fn from_edges_cached(
        n: usize,
        p: usize,
        edges: Vec<(Vertex, Vertex)>,
        cached_vertex_counts: Option<Vec<u64>>,
    ) -> Self {
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        let p = p.max(1);
        let len = edges.len();
        let t = pool::global()
            .threads()
            .clamp(1, len.div_ceil(1 << 14).max(1));
        let edges_ro: &[(Vertex, Vertex)] = &edges;
        let parts: Vec<Vec<Vec<(Vertex, Vertex)>>> = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(len, t, i);
                    let part = &edges_ro[a..b];
                    move || {
                        let mut buckets: Vec<Vec<(Vertex, Vertex)>> =
                            (0..p).map(|_| Vec::new()).collect();
                        for &(u, v) in part {
                            assert!(
                                (u as usize) < n && (v as usize) < n,
                                "edge ({u},{v}) out of range n={n}"
                            );
                            buckets[machine_of(u.min(v) as u64, p)].push((u, v));
                        }
                        buckets
                    }
                })
                .collect(),
        );
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = (0..p).map(|_| Vec::new()).collect();
        for part in parts {
            for (dst, src) in buckets.iter_mut().zip(part) {
                dst.extend(src);
            }
        }
        finish_shards(n, buckets, cached_vertex_counts)
    }

    /// Shard a flat (already normalized) [`Graph`] — the ingest step.
    pub fn from_graph(g: &Graph, p: usize) -> Self {
        Self::from_edges(g.num_vertices(), p, g.edges().to_vec())
    }

    /// Assemble from per-shard buckets produced by shard-aligned workers
    /// (the coordinator pipeline: worker `s` only ever receives edges with
    /// `machine_of(min endpoint) == s`).  Each bucket is normalized in
    /// place into its shard — no flat concatenation, no resharding.
    pub fn from_shard_buckets(n: usize, buckets: Vec<Vec<(Vertex, Vertex)>>) -> Self {
        assert!(!buckets.is_empty(), "need at least one shard");
        assert!(n <= u32::MAX as usize, "vertex ids are u32");
        finish_shards(n, buckets, None)
    }

    pub fn num_vertices(&self) -> usize {
        self.n
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn num_edges(&self) -> usize {
        self.shards.iter().map(|s| s.edges.len()).sum()
    }

    pub fn shards(&self) -> &[EdgeShard] {
        &self.shards
    }

    /// All edges, shard-major (shard order, sorted within each shard).
    pub fn iter_edges(&self) -> impl Iterator<Item = (Vertex, Vertex)> + '_ {
        self.shards.iter().flat_map(|s| s.edges.iter().copied())
    }

    /// Per-machine ownership histogram of the vertex id space.
    pub fn vertex_counts(&self) -> &[u64] {
        &self.vertex_counts
    }

    /// Flatten to the canonical [`Graph`] view (for the oracle, the dense
    /// backend boundary, and tests).  Bit-identical to `Graph::normalize`
    /// of the same edge multiset: shards are canonical and globally
    /// duplicate-free, so a global sort is all that remains.
    pub fn to_graph(&self) -> Graph {
        let mut edges: Vec<(Vertex, Vertex)> = Vec::with_capacity(self.num_edges());
        for s in &self.shards {
            edges.extend_from_slice(&s.edges);
        }
        // no dedup needed: equal edges share a shard, and shards are deduped
        crate::util::radix::par_sort_edge_pairs(&mut edges, false);
        Graph::from_edges_unchecked(self.n, edges)
    }

    /// Per-vertex degree via per-worker partial counts merged in fixed
    /// order (normalized-graph semantics, identical to `Graph::degrees`).
    pub fn degrees(&self) -> Vec<u32> {
        let n = self.n;
        let p = self.shards.len();
        let t = pool::global().threads().clamp(1, p);
        if t <= 1 {
            let mut deg = vec![0u32; n];
            for (u, v) in self.iter_edges() {
                deg[u as usize] += 1;
                deg[v as usize] += 1;
            }
            return deg;
        }
        let parts = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(p, t, i);
                    let shards = &self.shards[a..b];
                    move || {
                        let mut deg = vec![0u32; n];
                        for s in shards {
                            for &(u, v) in &s.edges {
                                deg[u as usize] += 1;
                                deg[v as usize] += 1;
                            }
                        }
                        deg
                    }
                })
                .collect(),
        );
        let mut deg = vec![0u32; n];
        for part in parts {
            for (d, c) in deg.iter_mut().zip(&part) {
                *d += c;
            }
        }
        deg
    }

    /// Rewrite every edge through `f` and re-bucket the results into their
    /// new owner shards **in the same pass** (the graph-layer half of the
    /// contraction rounds).  `f` returns rewritten endpoints or `None` to
    /// drop the edge; canonicalization, per-shard sort + dedup, and the
    /// cached histograms are rebuilt by [`finish_shards`].
    fn rewrite_into<F>(&self, new_n: usize, new_p: usize, f: F) -> ShardedGraph
    where
        F: Fn(Vertex, Vertex) -> Option<(Vertex, Vertex)> + Sync,
    {
        let p = self.shards.len();
        let t = pool::global().threads().clamp(1, p);
        let f = &f;
        let parts: Vec<Vec<Vec<(Vertex, Vertex)>>> = pool::global().run_jobs(
            (0..t)
                .map(|i| {
                    let (a, b) = chunk_range(p, t, i);
                    let shards = &self.shards[a..b];
                    move || {
                        let mut buckets: Vec<Vec<(Vertex, Vertex)>> =
                            (0..new_p).map(|_| Vec::new()).collect();
                        for s in shards {
                            for &(u, v) in &s.edges {
                                if let Some((x, y)) = f(u, v) {
                                    let (x, y) = if x <= y { (x, y) } else { (y, x) };
                                    if x != y {
                                        buckets[machine_of(x as u64, new_p)].push((x, y));
                                    }
                                }
                            }
                        }
                        buckets
                    }
                })
                .collect(),
        );
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = (0..new_p).map(|_| Vec::new()).collect();
        for part in parts {
            for (dst, src) in buckets.iter_mut().zip(part) {
                dst.extend(src);
            }
        }
        // vertex_counts is a pure function of (n, p): reuse the cache when
        // the rewrite keeps the vertex universe and shard count.
        let cached = if new_n == self.n && new_p == self.shards.len() {
            Some(self.vertex_counts.clone())
        } else {
            None
        };
        finish_shards(new_n, buckets, cached)
    }

    /// Contraction G/r of §2: vertices with equal labels merge; self-loops
    /// and duplicates vanish in the shard-local normalize.  Returns the
    /// contracted graph plus the old-vertex -> new-node compaction map
    /// (bit-identical to [`Graph::contract`] via the shared
    /// [`compact_labels`]).
    pub fn contract(&self, labels: &[Vertex]) -> (ShardedGraph, Vec<Vertex>) {
        assert_eq!(labels.len(), self.n, "labels len != n");
        let (compact, count) = compact_labels(labels, self.n);
        let compact_ref = &compact;
        let contracted = self.rewrite_into(count, self.shards.len(), |u, v| {
            Some((compact_ref[u as usize], compact_ref[v as usize]))
        });
        (contracted, compact)
    }

    /// Drop isolated vertices, compacting ids (§6).  Returns the pruned
    /// graph and the old-id -> Some(new-id) map (None for dropped
    /// vertices), matching `Graph::prune_isolated`.
    pub fn prune_isolated(&self) -> (ShardedGraph, Vec<Option<Vertex>>) {
        let deg = self.degrees();
        let mut map = vec![None; self.n];
        let mut next = 0u32;
        for v in 0..self.n {
            if deg[v] > 0 {
                map[v] = Some(next);
                next += 1;
            }
        }
        let map_ref = &map;
        let pruned = self.rewrite_into(next as usize, self.shards.len(), |u, v| {
            Some((map_ref[u as usize].unwrap(), map_ref[v as usize].unwrap()))
        });
        (pruned, map)
    }

    /// Re-partition to a different shard count (e.g. pipeline workers ->
    /// simulator machines).  Shard-to-shard: every input shard buckets its
    /// edges by the new ownership directly — the edge list is never
    /// flattened into one vector.
    pub fn reshard(&self, p: usize) -> ShardedGraph {
        let p = p.max(1);
        if p == self.shards.len() {
            return self.clone();
        }
        self.rewrite_into(self.n, p, |u, v| Some((u, v)))
    }

    /// Exact accounting of one neighborhood-hop round: every edge sends a
    /// fixed-size message to both endpoint keys (the left one lands on the
    /// owner shard by the invariant; the right one on the cached peer
    /// histogram), plus one self message per vertex when `include_self`.
    /// `msg_size` is the full wire size of one message (8-byte key +
    /// value).  A pure function of shard statistics — no `machine_of`
    /// per message.
    pub fn hop_charge(&self, msg_size: u64, include_self: bool) -> ShardRound {
        let p = self.shards.len();
        let m = self.num_edges() as u64;
        let mut machine_bytes = vec![0u64; p];
        for (s, shard) in self.shards.iter().enumerate() {
            machine_bytes[s] += msg_size * shard.edges.len() as u64;
            for (mb, &c) in machine_bytes.iter_mut().zip(&shard.peer_counts) {
                *mb += msg_size * c;
            }
        }
        let mut messages = 2 * m;
        if include_self {
            messages += self.n as u64;
            for (mb, &c) in machine_bytes.iter_mut().zip(&self.vertex_counts) {
                *mb += msg_size * c;
            }
        }
        ShardRound {
            messages,
            bytes: messages * msg_size,
            machine_bytes,
        }
    }

    /// Exact accounting of the two contraction rounds of Lemma 3.1
    /// (12-byte messages: 8-byte key + one endpoint).  Round 1 keys every
    /// edge by its left endpoint — the owner shard itself; round 2 by its
    /// right endpoint — the cached peer histogram.
    pub fn contract_charges(&self) -> (ShardRound, ShardRound) {
        let p = self.shards.len();
        let m = self.num_edges() as u64;
        let mut left = vec![0u64; p];
        let mut right = vec![0u64; p];
        for (s, shard) in self.shards.iter().enumerate() {
            left[s] = 12 * shard.edges.len() as u64;
            for (r, &c) in right.iter_mut().zip(&shard.peer_counts) {
                *r += 12 * c;
            }
        }
        (
            ShardRound {
                messages: m,
                bytes: 12 * m,
                machine_bytes: left,
            },
            ShardRound {
                messages: m,
                bytes: 12 * m,
                machine_bytes: right,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_raw(n: u64, m: usize, seed: u64) -> Vec<(Vertex, Vertex)> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| (rng.gen_range(n) as Vertex, rng.gen_range(n) as Vertex))
            .collect()
    }

    #[test]
    fn from_edges_matches_monolithic_normalize() {
        for p in [1usize, 4, 16] {
            for (n, m, seed) in [(50u64, 300usize, 1u64), (500, 8000, 2), (40, 0, 3)] {
                let raw = random_raw(n, m, seed);
                let flat = Graph::from_edges(n as usize, raw.clone());
                let sharded = ShardedGraph::from_edges(n as usize, p, raw);
                assert_eq!(sharded.to_graph(), flat, "p={p} n={n} m={m}");
                assert_eq!(sharded.num_edges(), flat.num_edges());
                assert_eq!(sharded.num_shards(), p);
            }
        }
    }

    #[test]
    fn shard_ownership_invariant_holds() {
        let raw = random_raw(200, 3000, 7);
        let g = ShardedGraph::from_edges(200, 8, raw);
        for (s, shard) in g.shards().iter().enumerate() {
            let mut prev: Option<(Vertex, Vertex)> = None;
            let mut peers = vec![0u64; 8];
            for &(u, v) in shard.edges() {
                assert!(u < v, "non-canonical ({u},{v})");
                assert_eq!(machine_of(u as u64, 8), s, "wrong owner for ({u},{v})");
                peers[machine_of(v as u64, 8)] += 1;
                if let Some(pv) = prev {
                    assert!(pv < (u, v), "not sorted/deduped");
                }
                prev = Some((u, v));
            }
            assert_eq!(peers, shard.peer_counts(), "peer histogram stale");
        }
        let total: u64 = g.vertex_counts().iter().sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn contract_matches_graph_contract() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(120, 900, 11);
            let flat = Graph::from_edges(120, raw.clone());
            let sharded = ShardedGraph::from_edges(120, p, raw);
            let labels: Vec<Vertex> = (0..120u32).map(|v| v % 37).collect();
            let (cf, mf) = flat.contract(&labels);
            let (cs, ms) = sharded.contract(&labels);
            assert_eq!(ms, mf, "p={p}: compaction maps differ");
            assert_eq!(cs.to_graph(), cf, "p={p}: contracted graphs differ");
        }
    }

    #[test]
    fn contract_sparse_labels_match_fallback() {
        let raw = vec![(0u32, 1u32), (1, 2)];
        let flat = Graph::from_edges(3, raw.clone());
        let sharded = ShardedGraph::from_edges(3, 4, raw);
        let labels = vec![1_000_000u32, 5, 5];
        let (cf, mf) = flat.contract(&labels);
        let (cs, ms) = sharded.contract(&labels);
        assert_eq!(ms, mf);
        assert_eq!(cs.to_graph(), cf);
    }

    #[test]
    fn degrees_and_prune_match_monolithic() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(80, 120, 21);
            let flat = Graph::from_edges(80, raw.clone());
            let sharded = ShardedGraph::from_edges(80, p, raw);
            assert_eq!(sharded.degrees(), flat.degrees(), "p={p}");
            let (pf, mapf) = flat.prune_isolated();
            let (ps, maps) = sharded.prune_isolated();
            assert_eq!(maps, mapf, "p={p}");
            assert_eq!(ps.to_graph(), pf, "p={p}");
        }
    }

    #[test]
    fn hop_charge_matches_per_message_accounting() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(150, 2000, 31);
            let g = ShardedGraph::from_edges(150, p, raw);
            for (msg_size, include_self) in [(12u64, true), (12, false), (16, true)] {
                let charge = g.hop_charge(msg_size, include_self);
                // brute force over the actual message multiset
                let mut mb = vec![0u64; p];
                let mut msgs = 0u64;
                for (u, v) in g.iter_edges() {
                    mb[machine_of(u as u64, p)] += msg_size;
                    mb[machine_of(v as u64, p)] += msg_size;
                    msgs += 2;
                }
                if include_self {
                    for v in 0..g.num_vertices() {
                        mb[machine_of(v as u64, p)] += msg_size;
                    }
                    msgs += g.num_vertices() as u64;
                }
                assert_eq!(charge.messages, msgs, "p={p}");
                assert_eq!(charge.bytes, msgs * msg_size, "p={p}");
                assert_eq!(charge.machine_bytes, mb, "p={p} self={include_self}");
            }
        }
    }

    #[test]
    fn contract_charges_match_per_message_accounting() {
        for p in [1usize, 4, 16] {
            let raw = random_raw(100, 1500, 41);
            let g = ShardedGraph::from_edges(100, p, raw);
            let (left, right) = g.contract_charges();
            let mut mb_left = vec![0u64; p];
            let mut mb_right = vec![0u64; p];
            for (u, v) in g.iter_edges() {
                mb_left[machine_of(u as u64, p)] += 12;
                mb_right[machine_of(v as u64, p)] += 12;
            }
            let m = g.num_edges() as u64;
            assert_eq!((left.messages, left.bytes), (m, 12 * m));
            assert_eq!((right.messages, right.bytes), (m, 12 * m));
            assert_eq!(left.machine_bytes, mb_left, "p={p}");
            assert_eq!(right.machine_bytes, mb_right, "p={p}");
        }
    }

    #[test]
    fn from_edges_like_matches_direct_build() {
        let a = ShardedGraph::from_edges(70, 4, random_raw(70, 300, 71));
        let b = a.from_edges_like(random_raw(70, 200, 72));
        let direct = ShardedGraph::from_edges(70, 4, random_raw(70, 200, 72));
        assert_eq!(b, direct);
        assert_eq!(b.vertex_counts(), a.vertex_counts());
    }

    #[test]
    fn reshard_preserves_the_graph() {
        let raw = random_raw(90, 700, 51);
        let g4 = ShardedGraph::from_edges(90, 4, raw.clone());
        let g16 = g4.reshard(16);
        let g1 = g16.reshard(1);
        assert_eq!(g16.num_shards(), 16);
        assert_eq!(g16.to_graph(), g4.to_graph());
        assert_eq!(g1.to_graph(), g4.to_graph());
        assert_eq!(g4.reshard(4), g4); // same count: clone
    }

    #[test]
    fn from_shard_buckets_accepts_worker_output() {
        // pipeline shape: raw (possibly reversed) edges, bucketed by the
        // min-endpoint hash at the generator
        let raw = random_raw(60, 400, 61);
        let p = 3;
        let mut buckets: Vec<Vec<(Vertex, Vertex)>> = vec![Vec::new(); p];
        for &(u, v) in &raw {
            if u != v {
                buckets[machine_of(u.min(v) as u64, p)].push((u, v));
            }
        }
        let g = ShardedGraph::from_shard_buckets(60, buckets);
        let flat = Graph::from_edges(60, raw);
        assert_eq!(g.to_graph(), flat);
    }

    #[test]
    fn empty_and_single_shard() {
        let g = ShardedGraph::empty(5, 4);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.degrees(), vec![0; 5]);
        let charge = g.hop_charge(12, true);
        assert_eq!(charge.messages, 5);
        let g1 = ShardedGraph::from_edges(3, 1, vec![(0, 1), (1, 0), (2, 2)]);
        assert_eq!(g1.num_shards(), 1);
        assert_eq!(g1.to_graph().edges(), &[(0, 1)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn from_edges_rejects_out_of_range() {
        // single worker job: panic message survives (inline execution)
        let _ = ShardedGraph::from_edges(2, 1, vec![(0, 5)]);
    }
}
