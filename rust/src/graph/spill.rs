//! Out-of-core shard residency: disk-backed [`EdgeShard`] storage behind
//! the [`ShardStore`] abstraction.
//!
//! The paper's claim is scale: contractions over graphs whose edge sets
//! exceed one machine's RAM.  PR 2 made [`EdgeShard`] the unit of
//! residency; this module makes residency optional.  A
//! [`crate::graph::ShardedGraph`] stores its shards through one of two
//! [`ShardStore`] backends:
//!
//! * [`Resident`] — all shards in RAM (the PR 2 behavior, still the fast
//!   path when the graph fits the budget);
//! * [`Spilled`] — each shard streamed from its own checksummed binary
//!   file; only the cached [`ShardStats`] (edge count + `peer_counts`
//!   ownership histogram) stay in RAM.
//!
//! **Residency invariant.**  For a spilled graph, RAM holds only
//! per-shard statistics (`O(machines²)` words), the vertex-space arrays
//! (`O(n)`), and — during an operation — per worker thread, at most one
//! loaded shard (reads) or one staged destination bucket (rewrites;
//! bounded by `sources × distinct(dest)` via early dedup — see
//! `ShardedGraph::rewrite_streamed`).  The full edge set is never
//! materialized: mutating operations run load → rewrite → spill shard by
//! shard, and the round accounting needs no edges at all because the
//! per-machine charges are pure functions of the cached stats
//! ([`crate::graph::ShardedGraph::hop_charge`]).
//!
//! The budget governs the *graph representation*.  The contraction-loop
//! algorithms (`lc`, `lc-mtl`, `tc`, `tc-dht`, `hash-min`) stream their
//! rounds and stay within it; the cluster-growing baselines (`cracker`'s
//! rewire output, `two-phase`'s star messages, `htm`'s cluster state)
//! additionally materialize O(m) round state by their own semantics —
//! they run correctly over spilled shards but are not bounded by the
//! budget.
//!
//! **File framing** (shared little-endian pair payload with
//! [`super::io`]): `LCCSHRD1 | shard u32 | num_shards u32 | m u64 |
//! fnv1a64(payload) u64 | m × (u32, u32)`.  Readers validate the header's
//! edge count against the actual file length *before* allocating, then
//! verify the payload checksum — truncation, corruption, and vanished
//! files surface as typed [`SpillError`]s, never as silently-wrong edges.

use std::fmt;
use std::fs::{self, File};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::edgelist::Vertex;
use super::io::{write_pairs, PAIR_BYTES};
use crate::mpc::simulator::machine_of;

/// Magic of one spilled shard file.
pub const SHARD_MAGIC: &[u8; 8] = b"LCCSHRD1";
/// Magic of a persisted spill manifest.
pub const MANIFEST_MAGIC: &[u8; 8] = b"LCCSPILL";
/// File name of the manifest inside a persisted spill directory.
pub const MANIFEST_NAME: &str = "manifest.lcm";
/// Bytes of RAM one resident edge costs (the budget unit).
pub const EDGE_BYTES: u64 = PAIR_BYTES;

/// magic + shard + num_shards + m + checksum.
const SHARD_HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;

/// File name of shard `s` inside a spill directory.
pub fn shard_file_name(s: usize) -> String {
    format!("shard-{s:05}.lcs")
}

// ---------------------------------------------------------------------------
// errors

/// Typed failures of the spill layer.  Every on-disk fault mode the store
/// can hit has its own variant so callers (and the fault-injection tests)
/// can distinguish them; none of them panic.
#[derive(Debug)]
pub enum SpillError {
    /// Underlying filesystem failure (including a spill directory deleted
    /// mid-run).
    Io {
        path: PathBuf,
        op: &'static str,
        source: std::io::Error,
    },
    /// The file does not start with the expected magic.
    BadMagic { path: PathBuf },
    /// The header's edge count disagrees with the actual file length.
    Truncated {
        path: PathBuf,
        expected_bytes: u64,
        actual_bytes: u64,
    },
    /// The payload does not hash to the header checksum.
    ChecksumMismatch {
        path: PathBuf,
        expected: u64,
        actual: u64,
    },
    /// Structurally valid file whose metadata disagrees with the store
    /// (wrong shard index, wrong shard count, stale manifest, ...).
    Corrupt { path: PathBuf, detail: String },
}

impl SpillError {
    pub(crate) fn io(path: &Path, op: &'static str, source: std::io::Error) -> SpillError {
        SpillError::Io {
            path: path.to_path_buf(),
            op,
            source,
        }
    }

    /// The file the error is about.
    pub fn path(&self) -> &Path {
        match self {
            SpillError::Io { path, .. }
            | SpillError::BadMagic { path }
            | SpillError::Truncated { path, .. }
            | SpillError::ChecksumMismatch { path, .. }
            | SpillError::Corrupt { path, .. } => path,
        }
    }
}

impl fmt::Display for SpillError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpillError::Io { path, op, source } => {
                write!(f, "spill I/O: {op} {}: {source}", path.display())
            }
            SpillError::BadMagic { path } => {
                write!(f, "{}: not a spill file (bad magic)", path.display())
            }
            SpillError::Truncated {
                path,
                expected_bytes,
                actual_bytes,
            } => write!(
                f,
                "{}: header claims {expected_bytes} bytes but the file is \
                 {actual_bytes} — truncated or corrupt",
                path.display()
            ),
            SpillError::ChecksumMismatch {
                path,
                expected,
                actual,
            } => write!(
                f,
                "{}: payload checksum {actual:#018x} != header {expected:#018x}",
                path.display()
            ),
            SpillError::Corrupt { path, detail } => {
                write!(f, "{}: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for SpillError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpillError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Streaming FNV-1a 64 — the one hash behind every checksum in this
/// module (shard payloads and manifest bodies share constants and
/// therefore on-disk compatibility).
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// [`Fnv1a`] over the little-endian pair encoding of `edges` — the
/// payload checksum of the shard framing.
pub fn checksum_edges(edges: &[(Vertex, Vertex)]) -> u64 {
    let mut h = Fnv1a::new();
    for &(u, v) in edges {
        h.update(&u.to_le_bytes());
        h.update(&v.to_le_bytes());
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// shard content + cached statistics

/// The RAM-cached statistics of one shard: everything the round accounting
/// needs, kept resident even when the edges are on disk.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    /// Number of edges in the shard.
    pub len: u64,
    /// `peer_counts[j]` = edges of the shard whose max endpoint is owned
    /// by machine `j`.
    pub peer_counts: Vec<u64>,
}

impl ShardStats {
    /// Compute from canonical shard edges.  Debug builds verify the
    /// shard-ownership invariant (`machine_of(min endpoint) == s`).
    pub fn from_edges(edges: &[(Vertex, Vertex)], p: usize, s: usize) -> ShardStats {
        let mut peer_counts = vec![0u64; p];
        for &(u, v) in edges {
            debug_assert!(u < v, "non-canonical edge ({u},{v})");
            debug_assert_eq!(
                machine_of(u as u64, p),
                s,
                "edge ({u},{v}) stored on the wrong shard"
            );
            peer_counts[machine_of(v as u64, p)] += 1;
        }
        let _ = s;
        ShardStats {
            len: edges.len() as u64,
            peer_counts,
        }
    }
}

/// One machine's slice of the edge list plus its cached statistics — the
/// unit of residency.  In a [`Resident`] store the whole struct lives in
/// RAM; in a [`Spilled`] store only the stats do, and the edges stream
/// from the shard's file.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeShard {
    /// Canonical `(min, max)` edges owned by this shard: sorted, deduped,
    /// no self-loops, `machine_of(min) == shard index`.
    edges: Vec<(Vertex, Vertex)>,
    stats: ShardStats,
}

impl EdgeShard {
    /// Build from canonical edges (sorted, deduped, loop-free, owned by
    /// shard `s` of `p`).
    pub fn new_canonical(edges: Vec<(Vertex, Vertex)>, p: usize, s: usize) -> EdgeShard {
        let stats = ShardStats::from_edges(&edges, p, s);
        EdgeShard { edges, stats }
    }

    /// Rebuild from canonical edges whose statistics are already known —
    /// the un-spill path, where stats live in RAM while the edges come
    /// off a validated shard file.  Debug builds re-derive and compare.
    pub fn with_stats(
        edges: Vec<(Vertex, Vertex)>,
        stats: ShardStats,
        p: usize,
        s: usize,
    ) -> EdgeShard {
        debug_assert_eq!(stats, ShardStats::from_edges(&edges, p, s));
        let _ = (p, s);
        EdgeShard { edges, stats }
    }

    pub fn edges(&self) -> &[(Vertex, Vertex)] {
        &self.edges
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Per-machine ownership histogram of this shard's right endpoints.
    pub fn peer_counts(&self) -> &[u64] {
        &self.stats.peer_counts
    }

    pub fn stats(&self) -> &ShardStats {
        &self.stats
    }

    pub fn into_edges(self) -> Vec<(Vertex, Vertex)> {
        self.edges
    }
}

/// A borrow-or-load view of one shard's edges: `Borrowed` from a resident
/// store (zero-copy), `Loaded` from a spill file (owned, freed when the
/// view drops — the "at most one shard per worker" half of the residency
/// invariant).
#[derive(Debug)]
pub enum ShardData<'a> {
    Borrowed(&'a [(Vertex, Vertex)]),
    Loaded(Vec<(Vertex, Vertex)>),
}

impl std::ops::Deref for ShardData<'_> {
    type Target = [(Vertex, Vertex)];
    fn deref(&self) -> &[(Vertex, Vertex)] {
        match self {
            ShardData::Borrowed(e) => e,
            ShardData::Loaded(e) => e,
        }
    }
}

impl ShardData<'_> {
    pub fn into_vec(self) -> Vec<(Vertex, Vertex)> {
        match self {
            ShardData::Borrowed(e) => e.to_vec(),
            ShardData::Loaded(e) => e,
        }
    }
}

/// Owning edge iterator over a [`ShardData`] view.
pub enum ShardDataIter<'a> {
    Borrowed(std::iter::Copied<std::slice::Iter<'a, (Vertex, Vertex)>>),
    Loaded(std::vec::IntoIter<(Vertex, Vertex)>),
}

impl Iterator for ShardDataIter<'_> {
    type Item = (Vertex, Vertex);
    #[inline]
    fn next(&mut self) -> Option<(Vertex, Vertex)> {
        match self {
            ShardDataIter::Borrowed(it) => it.next(),
            ShardDataIter::Loaded(it) => it.next(),
        }
    }
    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            ShardDataIter::Borrowed(it) => it.size_hint(),
            ShardDataIter::Loaded(it) => it.size_hint(),
        }
    }
}

impl<'a> IntoIterator for ShardData<'a> {
    type Item = (Vertex, Vertex);
    type IntoIter = ShardDataIter<'a>;
    fn into_iter(self) -> ShardDataIter<'a> {
        match self {
            ShardData::Borrowed(e) => ShardDataIter::Borrowed(e.iter().copied()),
            ShardData::Loaded(e) => ShardDataIter::Loaded(e.into_iter()),
        }
    }
}

// ---------------------------------------------------------------------------
// residency policy

/// When to trade RAM for disk.
#[derive(Debug, Clone, Default)]
pub struct SpillPolicy {
    /// Maximum bytes of resident edge data per graph; edge sets larger
    /// than this live on disk.  `None` = unbounded (always resident).
    pub budget_bytes: Option<u64>,
    /// Root directory for spill files (default: the OS temp dir).  Each
    /// graph generation gets its own subdirectory, removed when the last
    /// clone of the graph drops.
    pub root: Option<PathBuf>,
}

impl SpillPolicy {
    /// Unbounded: never spill (the default, and the PR 2 behavior).
    pub fn unbounded() -> SpillPolicy {
        SpillPolicy::default()
    }

    /// Spill whenever resident edge bytes would exceed `bytes`.
    pub fn budget(bytes: u64) -> SpillPolicy {
        SpillPolicy {
            budget_bytes: Some(bytes),
            root: None,
        }
    }

    /// From an optional budget (the `MpcConfig::spill_budget` /
    /// `--spill-budget` plumbing shape).
    pub fn with_budget(budget: Option<u64>) -> SpillPolicy {
        SpillPolicy {
            budget_bytes: budget,
            root: None,
        }
    }

    /// Should a graph of `edge_bytes` resident bytes spill?
    pub fn should_spill(&self, edge_bytes: u64) -> bool {
        self.budget_bytes.map_or(false, |b| edge_bytes > b)
    }
}

// ---------------------------------------------------------------------------
// spill directories

/// A spill directory owned by one graph generation.  Created uniquely
/// under the policy root; removed (with its files) on drop — except for
/// adopted directories (persisted spills opened via
/// `ShardedGraph::open_spilled`), which belong to the user.
#[derive(Debug)]
pub struct SpillDir {
    path: PathBuf,
    owned: bool,
}

static DIR_COUNTER: AtomicU64 = AtomicU64::new(0);

impl SpillDir {
    /// Create a fresh uniquely-named directory under `root` (OS temp dir
    /// when `None`).
    pub fn create_temp(root: Option<&Path>) -> Result<SpillDir, SpillError> {
        let base = root
            .map(Path::to_path_buf)
            .unwrap_or_else(std::env::temp_dir);
        let path = base.join(format!(
            "lcc-spill-{}-{}",
            std::process::id(),
            DIR_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&path).map_err(|e| SpillError::io(&path, "create dir", e))?;
        Ok(SpillDir { path, owned: true })
    }

    /// Wrap an existing user-owned directory (not removed on drop).
    pub fn adopt(path: PathBuf) -> SpillDir {
        SpillDir { path, owned: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        if self.owned {
            let _ = fs::remove_dir_all(&self.path);
        }
    }
}

// ---------------------------------------------------------------------------
// shard file framing

/// Encode one shard's canonical edges as a complete shard-file image
/// (header + payload) in memory, returning the bytes and the payload
/// checksum.  This is the **shard wire format**: [`write_shard_file`]
/// writes exactly these bytes, and the multi-process transport
/// (`crate::mpc::net`) ships them verbatim when distributing shards to
/// worker processes — so a spilled shard file can go on the wire without
/// rehydration, and a resident shard serializes identically.
pub fn encode_shard_bytes(
    shard: u32,
    num_shards: u32,
    edges: &[(Vertex, Vertex)],
) -> (Vec<u8>, u64) {
    let checksum = checksum_edges(edges);
    let mut out =
        Vec::with_capacity(SHARD_HEADER_BYTES as usize + edges.len() * PAIR_BYTES as usize);
    out.extend_from_slice(SHARD_MAGIC);
    out.extend_from_slice(&shard.to_le_bytes());
    out.extend_from_slice(&num_shards.to_le_bytes());
    out.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum.to_le_bytes());
    write_pairs(&mut out, edges).expect("infallible Vec write");
    (out, checksum)
}

/// Write one shard's canonical edges as a checksummed shard file —
/// streamed through a [`BufWriter`], byte-identical to
/// [`encode_shard_bytes`] (spilling runs exactly when memory is tight,
/// so the file path must not materialize a second copy of the shard).
/// Returns the payload checksum (recorded in manifests).
pub fn write_shard_file(
    path: &Path,
    shard: u32,
    num_shards: u32,
    edges: &[(Vertex, Vertex)],
) -> Result<u64, SpillError> {
    let f = File::create(path).map_err(|e| SpillError::io(path, "create", e))?;
    let mut w = BufWriter::new(f);
    let checksum = checksum_edges(edges);
    let write = |w: &mut BufWriter<File>| -> std::io::Result<()> {
        w.write_all(SHARD_MAGIC)?;
        w.write_all(&shard.to_le_bytes())?;
        w.write_all(&num_shards.to_le_bytes())?;
        w.write_all(&(edges.len() as u64).to_le_bytes())?;
        w.write_all(&checksum.to_le_bytes())?;
        write_pairs(w, edges)?;
        w.flush()
    };
    write(&mut w).map_err(|e| SpillError::io(path, "write", e))?;
    Ok(checksum)
}

/// Check a shard file's header-claimed size against the actual file
/// length without reading the payload (the cheap validation
/// `ShardedGraph::open_spilled` runs eagerly per shard).
pub fn validate_shard_file_len(path: &Path, expected_edges: u64) -> Result<(), SpillError> {
    let actual = fs::metadata(path)
        .map_err(|e| SpillError::io(path, "stat", e))?
        .len();
    let expected = expected_edges
        .checked_mul(PAIR_BYTES)
        .and_then(|p| p.checked_add(SHARD_HEADER_BYTES))
        .ok_or_else(|| SpillError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("edge count {expected_edges} overflows the file length"),
        })?;
    if actual != expected {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: expected,
            actual_bytes: actual,
        });
    }
    Ok(())
}

/// Parse and fully validate one shard-file image from memory: magic,
/// shard identity, header count vs actual length (before allocating the
/// edge vector), payload checksum.  Returns the edges plus the verified
/// payload checksum.  `origin` names the byte source in errors (a file
/// path, or a synthetic name like `<frame>` for transport traffic).
///
/// This is the read half of the shard wire format
/// ([`encode_shard_bytes`]): shard files on disk and shards shipped to
/// worker processes validate through the same code.
pub fn read_shard_bytes(
    bytes: &[u8],
    shard: u32,
    num_shards: u32,
    origin: &Path,
) -> Result<(Vec<(Vertex, Vertex)>, u64), SpillError> {
    let actual_len = bytes.len() as u64;
    if actual_len < SHARD_HEADER_BYTES {
        return Err(SpillError::Truncated {
            path: origin.to_path_buf(),
            expected_bytes: SHARD_HEADER_BYTES,
            actual_bytes: actual_len,
        });
    }
    if &bytes[..8] != SHARD_MAGIC {
        return Err(SpillError::BadMagic {
            path: origin.to_path_buf(),
        });
    }
    let got_shard = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let got_p = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    if (got_shard, got_p) != (shard, num_shards) {
        return Err(SpillError::Corrupt {
            path: origin.to_path_buf(),
            detail: format!(
                "file is shard {got_shard}/{got_p}, store expected {shard}/{num_shards}"
            ),
        });
    }
    let m = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let expected_checksum = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    // validate the claimed count against the actual length BEFORE allocating
    let expected_len = m
        .checked_mul(PAIR_BYTES)
        .and_then(|p| p.checked_add(SHARD_HEADER_BYTES));
    match expected_len {
        Some(expected) if expected == actual_len => {}
        _ => {
            return Err(SpillError::Truncated {
                path: origin.to_path_buf(),
                expected_bytes: expected_len.unwrap_or(u64::MAX),
                actual_bytes: actual_len,
            })
        }
    }
    let payload = &bytes[SHARD_HEADER_BYTES as usize..];
    let mut h = Fnv1a::new();
    h.update(payload);
    let actual_checksum = h.finish();
    if actual_checksum != expected_checksum {
        return Err(SpillError::ChecksumMismatch {
            path: origin.to_path_buf(),
            expected: expected_checksum,
            actual: actual_checksum,
        });
    }
    Ok((crate::graph::io::decode_pairs(payload), actual_checksum))
}

thread_local! {
    /// Per-worker reusable file-image buffer for spilled shard loads.
    /// Every pool worker streams one shard at a time (the residency
    /// invariant), so one buffer per thread turns the per-load file-image
    /// allocation + 8-byte-at-a-time `read_exact` loop into a single
    /// bulk read into warm memory; only the returned edge vector is
    /// allocated fresh.  §Perf: measured by the spilled `lcc perf` rows.
    static READ_BUF: std::cell::RefCell<Vec<u8>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Retained capacity cap for [`READ_BUF`]: reuse serves the per-round
/// load loop, not a permanent high-water reservation — a one-off giant
/// shard must not pin `threads × shard` bytes for the process lifetime
/// (spilling runs exactly when memory is tight).
const READ_BUF_RETAIN: usize = 8 << 20;

fn trim_read_buf(buf: &mut Vec<u8>) {
    if buf.capacity() > READ_BUF_RETAIN {
        buf.clear();
        buf.shrink_to(READ_BUF_RETAIN);
    }
}

/// Read a whole file into the thread-local reuse buffer.
fn read_file_reusing(path: &Path, buf: &mut Vec<u8>) -> Result<(), SpillError> {
    let mut f = File::open(path).map_err(|e| SpillError::io(path, "open", e))?;
    let len = f
        .metadata()
        .map_err(|e| SpillError::io(path, "stat", e))?
        .len();
    buf.clear();
    buf.reserve(len as usize);
    f.read_to_end(buf)
        .map_err(|e| SpillError::io(path, "read", e))?;
    Ok(())
}

/// Read and fully validate one shard file (see [`read_shard_bytes`] for
/// the checks).  The file image lands in the calling worker's reusable
/// read buffer; only the decoded edges are freshly allocated.
pub fn read_shard_file(
    path: &Path,
    shard: u32,
    num_shards: u32,
) -> Result<(Vec<(Vertex, Vertex)>, u64), SpillError> {
    READ_BUF.with(|b| {
        let mut buf = b.borrow_mut();
        read_file_reusing(path, &mut buf)?;
        let result = read_shard_bytes(&buf, shard, num_shards, path);
        trim_read_buf(&mut buf);
        result
    })
}

/// Read an unframed staging file of raw pairs (`len` from a prior stat —
/// transient rewrite intermediates, no checksum).  Shares the per-worker
/// read buffer with [`read_shard_file`].
pub fn read_raw_pairs(path: &Path, len: u64) -> Result<Vec<(Vertex, Vertex)>, SpillError> {
    if len % PAIR_BYTES != 0 {
        return Err(SpillError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("staging length {len} is not a multiple of {PAIR_BYTES}"),
        });
    }
    READ_BUF.with(|b| {
        let mut buf = b.borrow_mut();
        read_file_reusing(path, &mut buf)?;
        let result = if buf.len() as u64 != len {
            Err(SpillError::Truncated {
                path: path.to_path_buf(),
                expected_bytes: len,
                actual_bytes: buf.len() as u64,
            })
        } else {
            Ok(crate::graph::io::decode_pairs(&buf))
        };
        trim_read_buf(&mut buf);
        result
    })
}

// ---------------------------------------------------------------------------
// the store abstraction

/// Shard storage backend: uniform access to shard statistics (always in
/// RAM) and shard edges (in RAM or streamed from disk).
pub trait ShardStore {
    fn num_shards(&self) -> usize;

    /// Cached statistics of shard `s` — never touches disk.
    fn stats(&self, s: usize) -> &ShardStats;

    /// The edges of shard `s`: borrowed from a resident store, loaded and
    /// validated from a spilled one.
    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError>;

    fn is_spilled(&self) -> bool;
}

/// All shards in RAM (the fast path).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Resident {
    shards: Vec<EdgeShard>,
}

impl Resident {
    pub fn new(shards: Vec<EdgeShard>) -> Resident {
        Resident { shards }
    }

    pub fn shards(&self) -> &[EdgeShard] {
        &self.shards
    }

    pub fn into_shards(self) -> Vec<EdgeShard> {
        self.shards
    }
}

impl ShardStore for Resident {
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn stats(&self, s: usize) -> &ShardStats {
        self.shards[s].stats()
    }

    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError> {
        Ok(ShardData::Borrowed(self.shards[s].edges()))
    }

    fn is_spilled(&self) -> bool {
        false
    }
}

/// Metadata of one spilled shard (the RAM footprint of the shard).
#[derive(Debug, Clone)]
pub struct SpilledShard {
    pub path: PathBuf,
    pub stats: ShardStats,
    pub checksum: u64,
}

/// All shards on disk; clones share the directory via `Arc` (shard files
/// are immutable once written — every mutation builds a new generation).
#[derive(Debug, Clone)]
pub struct Spilled {
    dir: std::sync::Arc<SpillDir>,
    shards: Vec<SpilledShard>,
}

impl Spilled {
    pub fn from_parts(dir: std::sync::Arc<SpillDir>, shards: Vec<SpilledShard>) -> Spilled {
        Spilled { dir, shards }
    }

    pub fn dir(&self) -> &Path {
        self.dir.path()
    }

    /// RAM-cached per-shard metadata (stats + payload checksums).
    pub fn shard_metas(&self) -> &[SpilledShard] {
        &self.shards
    }
}

impl ShardStore for Spilled {
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn stats(&self, s: usize) -> &ShardStats {
        &self.shards[s].stats
    }

    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError> {
        let meta = &self.shards[s];
        let (edges, checksum) =
            read_shard_file(&meta.path, s as u32, self.shards.len() as u32)?;
        if edges.len() as u64 != meta.stats.len {
            return Err(SpillError::Corrupt {
                path: meta.path.clone(),
                detail: format!(
                    "file holds {} edges, store expected {}",
                    edges.len(),
                    meta.stats.len
                ),
            });
        }
        // the file's header checksum only proves self-consistency; the
        // store's cached checksum pins the *generation* — a stale but
        // intact file (e.g. an interrupted re-persist) must not be read
        // as if it matched the RAM-cached stats
        if checksum != meta.checksum {
            return Err(SpillError::ChecksumMismatch {
                path: meta.path.clone(),
                expected: meta.checksum,
                actual: checksum,
            });
        }
        Ok(ShardData::Loaded(edges))
    }

    fn is_spilled(&self) -> bool {
        true
    }
}

/// Write one finalized shard into `dir`, returning its spilled metadata.
pub fn spill_shard(
    dir: &SpillDir,
    s: usize,
    num_shards: usize,
    shard: &EdgeShard,
) -> Result<SpilledShard, SpillError> {
    let path = dir.path().join(shard_file_name(s));
    let checksum = write_shard_file(&path, s as u32, num_shards as u32, shard.edges())?;
    Ok(SpilledShard {
        path,
        stats: shard.stats().clone(),
        checksum,
    })
}

// ---------------------------------------------------------------------------
// persisted-spill manifest (crash-then-reload)

/// Per-shard manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestShard {
    pub len: u64,
    pub checksum: u64,
    pub peer_counts: Vec<u64>,
}

/// Manifest of a persisted spilled graph: enough to rebuild the store's
/// RAM-cached state without reading any shard payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub n: u64,
    pub p: u32,
    pub shards: Vec<ManifestShard>,
}

/// Crash-consistent file replacement: write the full image to a sibling
/// `.tmp` file, fsync it, then atomically rename over `path`.  A crash at
/// any point leaves either the old file intact or the new one complete —
/// never a torn mix — which is what lets the manifest double as a
/// recovery checkpoint.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), SpillError> {
    let tmp = match (path.parent(), path.file_name()) {
        (Some(dir), Some(name)) => {
            let mut t = name.to_os_string();
            t.push(".tmp");
            dir.join(t)
        }
        _ => {
            return Err(SpillError::Corrupt {
                path: path.to_path_buf(),
                detail: "atomic write target has no parent directory".into(),
            })
        }
    };
    let write = || -> std::io::Result<()> {
        let f = File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        w.write_all(bytes)?;
        w.flush()?;
        // fsync before the rename: the rename must never become durable
        // ahead of the data it points at
        w.get_ref().sync_all()
    };
    write().map_err(|e| SpillError::io(&tmp, "write", e))?;
    fs::rename(&tmp, path).map_err(|e| SpillError::io(path, "rename", e))
}

/// Serialize + write a manifest (body FNV-checksummed like the shards),
/// via tmp-write + fsync + atomic rename: a crash mid-write can never
/// leave a torn manifest in place of a valid one.
pub fn write_manifest(path: &Path, m: &Manifest) -> Result<(), SpillError> {
    let mut body: Vec<u8> = Vec::new();
    body.extend_from_slice(&m.n.to_le_bytes());
    body.extend_from_slice(&m.p.to_le_bytes());
    for sh in &m.shards {
        body.extend_from_slice(&sh.len.to_le_bytes());
        body.extend_from_slice(&sh.checksum.to_le_bytes());
        for &c in &sh.peer_counts {
            body.extend_from_slice(&c.to_le_bytes());
        }
    }
    let mut h = Fnv1a::new();
    h.update(&body);
    let h = h.finish();
    let mut image = Vec::with_capacity(8 + body.len() + 8);
    image.extend_from_slice(MANIFEST_MAGIC);
    image.extend_from_slice(&body);
    image.extend_from_slice(&h.to_le_bytes());
    write_atomic(path, &image)
}

/// Read + validate a manifest (magic, exact length, body checksum).
pub fn read_manifest(path: &Path) -> Result<Manifest, SpillError> {
    let bytes = fs::read(path).map_err(|e| SpillError::io(path, "read", e))?;
    let corrupt = |detail: String| SpillError::Corrupt {
        path: path.to_path_buf(),
        detail,
    };
    if bytes.len() < 8 + 8 + 4 + 8 {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: (8 + 8 + 4 + 8) as u64,
            actual_bytes: bytes.len() as u64,
        });
    }
    if &bytes[..8] != MANIFEST_MAGIC {
        return Err(SpillError::BadMagic {
            path: path.to_path_buf(),
        });
    }
    let body = &bytes[8..bytes.len() - 8];
    let mut fnv = Fnv1a::new();
    fnv.update(body);
    let h = fnv.finish();
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if h != stored {
        return Err(SpillError::ChecksumMismatch {
            path: path.to_path_buf(),
            expected: stored,
            actual: h,
        });
    }
    let u64_at = |off: usize| -> u64 { u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) };
    let n = u64_at(0);
    let p = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    // file-supplied p: checked arithmetic so a garbage count is a typed
    // Corrupt, not a debug-build overflow panic
    let per_shard = 8 + 8 + 8 * p; // p <= u32::MAX, so this term cannot overflow u64-sized usize
    per_shard
        .checked_mul(p)
        .and_then(|b| b.checked_add(12))
        .filter(|&b| b == body.len())
        .ok_or_else(|| {
            corrupt(format!(
                "manifest body is {} bytes, inconsistent with p={p}",
                body.len()
            ))
        })?;
    let mut shards = Vec::with_capacity(p);
    for s in 0..p {
        let off = 12 + s * per_shard;
        let len = u64_at(off);
        let checksum = u64_at(off + 8);
        let peer_counts: Vec<u64> = (0..p).map(|j| u64_at(off + 16 + 8 * j)).collect();
        if peer_counts.iter().sum::<u64>() != len {
            return Err(corrupt(format!(
                "shard {s}: peer_counts sum to {} but len is {len}",
                peer_counts.iter().sum::<u64>()
            )));
        }
        shards.push(ManifestShard {
            len,
            checksum,
            peer_counts,
        });
    }
    Ok(Manifest {
        n,
        p: p as u32,
        shards,
    })
}

// ---------------------------------------------------------------------------
// per-generation run checkpoint (fault-tolerant shuffle recovery)

/// Magic of a persisted run checkpoint.
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"LCCCKPT1";
/// File name of the checkpoint inside a checkpoint directory.
pub const CHECKPOINT_NAME: &str = "checkpoint.lcc";

/// Coordinator-side recovery state at one contraction generation
/// boundary: which graph generation the workers hold custody of (its
/// shard files live in `custody_dir`, in the spill framing), the content
/// hash of the value mirror, the run's RNG stream position, and the
/// transport round counter.  Written via [`write_atomic`] at every
/// custody change — a crash mid-write leaves the previous checkpoint
/// valid.
///
/// Layout: `LCCCKPT1 | generation u64 | machines u32 | mirror u8 |
/// mirror_hash u64 | rng_state 4×u64 | rounds u64 | dir_len u32 |
/// custody_dir | fnv1a64(body) u64`.
#[derive(Debug, Clone, PartialEq)]
pub struct RunCheckpoint {
    /// Generation id of the [`crate::graph::ShardedGraph`] checkpointed.
    pub generation: u64,
    pub machines: u32,
    /// Content hash of the worker value mirror (`None` before any sync).
    pub mirror_hash: Option<u64>,
    /// The run RNG's stream position (Xoshiro256++ state words).
    pub rng_state: [u64; 4],
    /// Transport round counter at the boundary (replayed rounds are
    /// charged once; this pins where the charge log stood).
    pub rounds: u64,
    /// Name of the per-generation shard directory, relative to the
    /// checkpoint directory (`gen-<generation>`).
    pub custody_dir: String,
}

/// Serialize + write a run checkpoint atomically ([`write_atomic`]).
pub fn write_checkpoint(path: &Path, c: &RunCheckpoint) -> Result<(), SpillError> {
    let dir = c.custody_dir.as_bytes();
    let mut body: Vec<u8> = Vec::with_capacity(8 + 4 + 1 + 8 + 32 + 8 + 4 + dir.len());
    body.extend_from_slice(&c.generation.to_le_bytes());
    body.extend_from_slice(&c.machines.to_le_bytes());
    body.push(u8::from(c.mirror_hash.is_some()));
    body.extend_from_slice(&c.mirror_hash.unwrap_or(0).to_le_bytes());
    for w in c.rng_state {
        body.extend_from_slice(&w.to_le_bytes());
    }
    body.extend_from_slice(&c.rounds.to_le_bytes());
    body.extend_from_slice(&(dir.len() as u32).to_le_bytes());
    body.extend_from_slice(dir);
    let mut h = Fnv1a::new();
    h.update(&body);
    let h = h.finish();
    let mut image = Vec::with_capacity(8 + body.len() + 8);
    image.extend_from_slice(CHECKPOINT_MAGIC);
    image.extend_from_slice(&body);
    image.extend_from_slice(&h.to_le_bytes());
    write_atomic(path, &image)
}

/// Read + validate a run checkpoint (magic, exact length, checksum).
pub fn read_checkpoint(path: &Path) -> Result<RunCheckpoint, SpillError> {
    let bytes = fs::read(path).map_err(|e| SpillError::io(path, "read", e))?;
    const FIXED: usize = 8 + 4 + 1 + 8 + 32 + 8 + 4;
    if bytes.len() < 8 + FIXED + 8 {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: (8 + FIXED + 8) as u64,
            actual_bytes: bytes.len() as u64,
        });
    }
    if &bytes[..8] != CHECKPOINT_MAGIC {
        return Err(SpillError::BadMagic {
            path: path.to_path_buf(),
        });
    }
    let body = &bytes[8..bytes.len() - 8];
    let mut fnv = Fnv1a::new();
    fnv.update(body);
    let h = fnv.finish();
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if h != stored {
        return Err(SpillError::ChecksumMismatch {
            path: path.to_path_buf(),
            expected: stored,
            actual: h,
        });
    }
    let corrupt = |detail: String| SpillError::Corrupt {
        path: path.to_path_buf(),
        detail,
    };
    let u64_at = |off: usize| -> u64 { u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) };
    let generation = u64_at(0);
    let machines = u32::from_le_bytes(body[8..12].try_into().unwrap());
    let mirror_hash = match body[12] {
        0 => None,
        1 => Some(u64_at(13)),
        tag => return Err(corrupt(format!("bad mirror-presence tag {tag}"))),
    };
    let mut rng_state = [0u64; 4];
    for (i, w) in rng_state.iter_mut().enumerate() {
        *w = u64_at(21 + 8 * i);
    }
    let rounds = u64_at(53);
    let dir_len = u32::from_le_bytes(body[61..65].try_into().unwrap()) as usize;
    if body.len() != FIXED + dir_len {
        return Err(corrupt(format!(
            "checkpoint body is {} bytes, inconsistent with dir_len={dir_len}",
            body.len()
        )));
    }
    let custody_dir = std::str::from_utf8(&body[65..])
        .map_err(|_| corrupt("custody dir name is not UTF-8".into()))?
        .to_string();
    Ok(RunCheckpoint {
        generation,
        machines,
        mirror_hash,
        rng_state,
        rounds,
        custody_dir,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> SpillDir {
        SpillDir::create_temp(None).unwrap()
    }

    fn canonical_edges(p: usize, s: usize) -> Vec<(Vertex, Vertex)> {
        // edges whose min endpoint is owned by shard s
        let mut edges: Vec<(Vertex, Vertex)> = (0u32..2000)
            .filter(|&u| machine_of(u as u64, p) == s)
            .map(|u| (u, u + 1 + (u % 7)))
            .collect();
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    #[test]
    fn shard_file_roundtrip() {
        let dir = tmp();
        let edges = canonical_edges(4, 1);
        let path = dir.path().join(shard_file_name(1));
        let ck = write_shard_file(&path, 1, 4, &edges).unwrap();
        assert_eq!(ck, checksum_edges(&edges));
        validate_shard_file_len(&path, edges.len() as u64).unwrap();
        assert_eq!(read_shard_file(&path, 1, 4).unwrap(), (edges, ck));
    }

    #[test]
    fn shard_bytes_roundtrip_matches_file_framing() {
        // the in-memory wire image IS the file image: encode → write,
        // fs::read → read_shard_bytes must agree with the file path
        let dir = tmp();
        let edges = canonical_edges(4, 2);
        let path = dir.path().join(shard_file_name(2));
        let (bytes, ck) = encode_shard_bytes(2, 4, &edges);
        let file_ck = write_shard_file(&path, 2, 4, &edges).unwrap();
        assert_eq!(ck, file_ck);
        assert_eq!(fs::read(&path).unwrap(), bytes);
        let (decoded, ck2) =
            read_shard_bytes(&bytes, 2, 4, Path::new("<frame>")).unwrap();
        assert_eq!((decoded, ck2), (edges, ck));
        // wrong identity and truncation are typed on the bytes path too
        assert!(matches!(
            read_shard_bytes(&bytes, 0, 4, Path::new("<frame>")),
            Err(SpillError::Corrupt { .. })
        ));
        assert!(matches!(
            read_shard_bytes(&bytes[..bytes.len() - 2], 2, 4, Path::new("<frame>")),
            Err(SpillError::Truncated { .. })
        ));
    }

    #[test]
    fn truncated_file_is_typed_error() {
        let dir = tmp();
        let edges = canonical_edges(4, 0);
        let path = dir.path().join(shard_file_name(0));
        write_shard_file(&path, 0, 4, &edges).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        match read_shard_file(&path, 0, 4) {
            Err(SpillError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // header shorter than minimal
        fs::write(&path, &bytes[..10]).unwrap();
        assert!(matches!(
            read_shard_file(&path, 0, 4),
            Err(SpillError::Truncated { .. })
        ));
    }

    #[test]
    fn corrupt_payload_is_checksum_mismatch() {
        let dir = tmp();
        let edges = canonical_edges(4, 2);
        let path = dir.path().join(shard_file_name(2));
        write_shard_file(&path, 2, 4, &edges).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_shard_file(&path, 2, 4),
            Err(SpillError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn wrong_identity_and_magic_are_typed() {
        let dir = tmp();
        let edges = canonical_edges(4, 3);
        let path = dir.path().join(shard_file_name(3));
        write_shard_file(&path, 3, 4, &edges).unwrap();
        assert!(matches!(
            read_shard_file(&path, 1, 4),
            Err(SpillError::Corrupt { .. })
        ));
        let mut bytes = fs::read(&path).unwrap();
        bytes[0] = b'X';
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_shard_file(&path, 3, 4),
            Err(SpillError::BadMagic { .. })
        ));
    }

    #[test]
    fn missing_file_is_io_error() {
        let dir = tmp();
        let path = dir.path().join(shard_file_name(0));
        match read_shard_file(&path, 0, 1) {
            Err(SpillError::Io { op, .. }) => assert_eq!(op, "open"),
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn manifest_roundtrip_and_corruption() {
        let dir = tmp();
        let m = Manifest {
            n: 100,
            p: 2,
            shards: vec![
                ManifestShard {
                    len: 3,
                    checksum: 7,
                    peer_counts: vec![1, 2],
                },
                ManifestShard {
                    len: 0,
                    checksum: 9,
                    peer_counts: vec![0, 0],
                },
            ],
        };
        let path = dir.path().join(MANIFEST_NAME);
        write_manifest(&path, &m).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m);
        let mut bytes = fs::read(&path).unwrap();
        bytes[10] ^= 1;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_manifest(&path),
            Err(SpillError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn checkpoint_roundtrip_and_corruption() {
        let dir = tmp();
        let c = RunCheckpoint {
            generation: 42,
            machines: 4,
            mirror_hash: Some(0xdead_beef_cafe_f00d),
            rng_state: [1, 2, 3, u64::MAX],
            rounds: 17,
            custody_dir: "gen-42".into(),
        };
        let path = dir.path().join(CHECKPOINT_NAME);
        write_checkpoint(&path, &c).unwrap();
        assert_eq!(read_checkpoint(&path).unwrap(), c);

        // no mirror yet
        let c2 = RunCheckpoint {
            mirror_hash: None,
            ..c.clone()
        };
        write_checkpoint(&path, &c2).unwrap();
        assert_eq!(read_checkpoint(&path).unwrap(), c2);

        // corruption is a typed checksum mismatch
        let mut bytes = fs::read(&path).unwrap();
        bytes[20] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::ChecksumMismatch { .. })
        ));
        // foreign file / truncation are typed too
        fs::write(&path, b"not a checkpoint").unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::Truncated { .. })
        ));
        fs::write(&path, [b"XXXXXXXX".as_slice(), &[0u8; 80]].concat()).unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::BadMagic { .. })
        ));
    }

    #[test]
    fn atomic_write_replaces_and_survives_stale_tmp() {
        let dir = tmp();
        let path = dir.path().join("target.bin");
        write_atomic(&path, b"first image").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first image");
        // a stale tmp from a crashed previous writer must not break the
        // next write — it is simply overwritten and renamed away
        fs::write(dir.path().join("target.bin.tmp"), b"torn garbage").unwrap();
        write_atomic(&path, b"second image").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second image");
        assert!(
            !dir.path().join("target.bin.tmp").exists(),
            "tmp renamed into place"
        );
    }

    #[test]
    fn spill_dir_removed_on_drop_but_adopted_kept() {
        let dir = tmp();
        let path = dir.path().to_path_buf();
        fs::write(path.join("x"), b"y").unwrap();
        drop(dir);
        assert!(!path.exists());

        let keep = std::env::temp_dir().join(format!("lcc-spill-keep-{}", std::process::id()));
        fs::create_dir_all(&keep).unwrap();
        drop(SpillDir::adopt(keep.clone()));
        assert!(keep.exists());
        let _ = fs::remove_dir_all(&keep);
    }
}
