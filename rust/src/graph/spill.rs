//! Out-of-core shard residency: disk-backed [`EdgeShard`] storage behind
//! the [`ShardStore`] abstraction.
//!
//! The paper's claim is scale: contractions over graphs whose edge sets
//! exceed one machine's RAM.  PR 2 made [`EdgeShard`] the unit of
//! residency; this module makes residency optional.  A
//! [`crate::graph::ShardedGraph`] stores its shards through one of two
//! [`ShardStore`] backends:
//!
//! * [`Resident`] — all shards in RAM (the PR 2 behavior, still the fast
//!   path when the graph fits the budget);
//! * [`Spilled`] — each shard streamed from its own checksummed binary
//!   file; only the cached [`ShardStats`] (edge count + `peer_counts`
//!   ownership histogram) stay in RAM.
//!
//! **Residency invariant.**  For a spilled graph, RAM holds only
//! per-shard statistics (`O(machines²)` words), the vertex-space arrays
//! (`O(n)`), and — during an operation — per worker thread, at most one
//! loaded shard (reads) or one staged destination bucket (rewrites;
//! bounded by `sources × distinct(dest)` via early dedup — see
//! `ShardedGraph::rewrite_streamed`).  The full edge set is never
//! materialized: mutating operations run load → rewrite → spill shard by
//! shard, and the round accounting needs no edges at all because the
//! per-machine charges are pure functions of the cached stats
//! ([`crate::graph::ShardedGraph::hop_charge`]).
//!
//! The budget governs the *graph representation*.  The contraction-loop
//! algorithms (`lc`, `lc-mtl`, `tc`, `tc-dht`, `hash-min`) stream their
//! rounds and stay within it; the cluster-growing baselines (`cracker`'s
//! rewire output, `two-phase`'s star messages, `htm`'s cluster state)
//! additionally materialize O(m) round state by their own semantics —
//! they run correctly over spilled shards but are not bounded by the
//! budget.
//!
//! **File framing.**  One columnar zero-copy layout serves disk and wire
//! — the file image written here is the frame body `crate::mpc::net`
//! ships, and both are read in place through a [`ShardCursor`] without
//! rehydrating a `Vec<(Vertex, Vertex)>`:
//!
//! ```text
//! off  len
//!   0    8  magic "LCCSHRD2"
//!   8    4  shard id (u32 LE)
//!  12    4  num_shards (u32 LE)
//!  16    8  m = edge count (u64 LE)
//!  24    8  fnv1a64 over the logical row-major LE pair encoding
//!  32    4  index bucket count B (u32 LE); min(m, 4096), 0 if unindexed
//!  36    4  index span = max(src) + 1 (u32 LE, saturating)
//!  40       src column: m × u32 LE
//!  40+4m    dst column: m × u32 LE
//!  40+8m    index offsets: (B+1) × u64 LE, present iff B > 0
//! ```
//!
//! The checksum stays the *logical* row-major pair hash
//! ([`checksum_edges`]) rather than a hash of the physical columns, so
//! manifests, transport acks, and generation pins written against the
//! legacy framing keep their values unchanged.  The index maps a source
//! vertex to bucket `v·B/span` (clamped), whose stored offset pair
//! brackets a binary search — O(1)+O(log(m/B)) per [`ShardCursor::
//! vertex_range`] lookup.  Every field is read via `from_le_bytes` on
//! byte slices, so images need no alignment: an mmap'd file and a frame
//! body at an arbitrary offset parse identically.
//!
//! Readers validate the header's edge count against the actual image
//! length *before* allocating, then verify the payload checksum and (the
//! checksum does not cover the index bytes) rebuild the expected index
//! from the src column during the same walk — truncation, corruption, a
//! lying header, and vanished files all surface as typed [`SpillError`]s,
//! never as silently-wrong edges.  The legacy row-major `LCCSHRD1`
//! framing (`header | m × (u32, u32)`) is still accepted on read, so
//! persisted spills from earlier generations reload.
//!
//! **Mmap data plane.**  [`Spilled`] loads map the shard file once per
//! generation (checksum + index verified on first touch, cached in the
//! store), after which every read re-parses only the 40-byte header and
//! iterates the borrowed columns in place: steady-state spilled rounds do
//! zero per-edge heap allocation, and the mapped pages are clean page
//! cache the kernel can evict and fault back on demand.  The
//! [`data_plane_counters`] atomics record bytes mapped vs copied so perf
//! runs (and CI) can prove the zero-copy path actually ran.

use std::fmt;
use std::fs::{self, File};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::edgelist::Vertex;
use super::io::PAIR_BYTES;
use crate::mpc::simulator::machine_of;

/// Magic of the legacy row-major shard framing (read-only compatibility).
pub const SHARD_MAGIC: &[u8; 8] = b"LCCSHRD1";
/// Magic of the columnar zero-copy shard framing (what we write).
pub const SHARD_MAGIC_V2: &[u8; 8] = b"LCCSHRD2";
/// Magic of a persisted spill manifest.
pub const MANIFEST_MAGIC: &[u8; 8] = b"LCCSPILL";
/// File name of the manifest inside a persisted spill directory.
pub const MANIFEST_NAME: &str = "manifest.lcm";
/// Bytes of RAM one resident edge costs (the budget unit).
pub const EDGE_BYTES: u64 = PAIR_BYTES;

/// Legacy header: magic + shard + num_shards + m + checksum.
const SHARD_HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;
/// Columnar header: legacy fields + index bucket count + index span.
const V2_HEADER_BYTES: u64 = SHARD_HEADER_BYTES + 4 + 4;
/// Cap on index buckets per shard: 4096 offsets (32 KiB) bound the index
/// to a rounding error of the file size while keeping buckets of ~m/4096
/// rows — small enough that the bracketed binary search touches one or
/// two cache lines of the src column.
const INDEX_MAX_BUCKETS: u64 = 4096;

/// Bucket count of a shard of `m` edges: one bucket per edge up to the
/// cap (an empty shard carries no index).
fn index_buckets(m: u64) -> u64 {
    m.min(INDEX_MAX_BUCKETS)
}

/// The bucket holding source vertex `v`: monotone in `v`, so equal
/// sources share a bucket and each bucket covers a contiguous row range
/// of the sorted src column.
#[inline]
fn index_bucket(v: Vertex, buckets: u64, span: u32) -> usize {
    debug_assert!(buckets > 0);
    let b = (v as u64 * buckets) / (span.max(1) as u64);
    b.min(buckets - 1) as usize
}

/// File name of shard `s` inside a spill directory.
pub fn shard_file_name(s: usize) -> String {
    format!("shard-{s:05}.lcs")
}

// ---------------------------------------------------------------------------
// errors

/// Typed failures of the spill layer.  Every on-disk fault mode the store
/// can hit has its own variant so callers (and the fault-injection tests)
/// can distinguish them; none of them panic.
#[derive(Debug)]
pub enum SpillError {
    /// Underlying filesystem failure (including a spill directory deleted
    /// mid-run).
    Io {
        path: PathBuf,
        op: &'static str,
        source: std::io::Error,
    },
    /// The file does not start with the expected magic.
    BadMagic { path: PathBuf },
    /// The header's edge count disagrees with the actual file length.
    Truncated {
        path: PathBuf,
        expected_bytes: u64,
        actual_bytes: u64,
    },
    /// The payload does not hash to the header checksum.
    ChecksumMismatch {
        path: PathBuf,
        expected: u64,
        actual: u64,
    },
    /// Structurally valid file whose metadata disagrees with the store
    /// (wrong shard index, wrong shard count, stale manifest, ...).
    Corrupt { path: PathBuf, detail: String },
}

impl SpillError {
    pub(crate) fn io(path: &Path, op: &'static str, source: std::io::Error) -> SpillError {
        SpillError::Io {
            path: path.to_path_buf(),
            op,
            source,
        }
    }

    /// The file the error is about.
    pub fn path(&self) -> &Path {
        match self {
            SpillError::Io { path, .. }
            | SpillError::BadMagic { path }
            | SpillError::Truncated { path, .. }
            | SpillError::ChecksumMismatch { path, .. }
            | SpillError::Corrupt { path, .. } => path,
        }
    }
}

impl fmt::Display for SpillError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpillError::Io { path, op, source } => {
                write!(f, "spill I/O: {op} {}: {source}", path.display())
            }
            SpillError::BadMagic { path } => {
                write!(f, "{}: not a spill file (bad magic)", path.display())
            }
            SpillError::Truncated {
                path,
                expected_bytes,
                actual_bytes,
            } => write!(
                f,
                "{}: header claims {expected_bytes} bytes but the file is \
                 {actual_bytes} — truncated or corrupt",
                path.display()
            ),
            SpillError::ChecksumMismatch {
                path,
                expected,
                actual,
            } => write!(
                f,
                "{}: payload checksum {actual:#018x} != header {expected:#018x}",
                path.display()
            ),
            SpillError::Corrupt { path, detail } => {
                write!(f, "{}: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for SpillError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpillError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Streaming FNV-1a 64 — the one hash behind every checksum in this
/// module (shard payloads and manifest bodies share constants and
/// therefore on-disk compatibility).
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// [`Fnv1a`] over the little-endian row-major pair encoding of a pair
/// stream — the payload checksum of the shard framing.  Streaming so
/// borrowed cursors (wire frames, mapped files) checksum without
/// collecting into a vector.
pub fn checksum_pairs<I: IntoIterator<Item = (Vertex, Vertex)>>(pairs: I) -> u64 {
    let mut h = Fnv1a::new();
    for (u, v) in pairs {
        h.update(&u.to_le_bytes());
        h.update(&v.to_le_bytes());
    }
    h.finish()
}

/// [`checksum_pairs`] over a slice of edges.
pub fn checksum_edges(edges: &[(Vertex, Vertex)]) -> u64 {
    checksum_pairs(edges.iter().copied())
}

// ---------------------------------------------------------------------------
// shard content + cached statistics

/// The RAM-cached statistics of one shard: everything the round accounting
/// needs, kept resident even when the edges are on disk.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    /// Number of edges in the shard.
    pub len: u64,
    /// `peer_counts[j]` = edges of the shard whose max endpoint is owned
    /// by machine `j`.
    pub peer_counts: Vec<u64>,
}

impl ShardStats {
    /// Compute from a canonical pair stream (a borrowed cursor or any
    /// edge iterator) without materializing it.  Debug builds verify the
    /// shard-ownership invariant (`machine_of(min endpoint) == s`).
    pub fn from_pairs<I: IntoIterator<Item = (Vertex, Vertex)>>(
        pairs: I,
        p: usize,
        s: usize,
    ) -> ShardStats {
        let mut peer_counts = vec![0u64; p];
        let mut len = 0u64;
        for (u, v) in pairs {
            debug_assert!(u < v, "non-canonical edge ({u},{v})");
            debug_assert_eq!(
                machine_of(u as u64, p),
                s,
                "edge ({u},{v}) stored on the wrong shard"
            );
            peer_counts[machine_of(v as u64, p)] += 1;
            len += 1;
        }
        let _ = s;
        ShardStats { len, peer_counts }
    }

    /// [`ShardStats::from_pairs`] over a slice of canonical edges.
    pub fn from_edges(edges: &[(Vertex, Vertex)], p: usize, s: usize) -> ShardStats {
        ShardStats::from_pairs(edges.iter().copied(), p, s)
    }
}

/// One machine's slice of the edge list plus its cached statistics — the
/// unit of residency.  In a [`Resident`] store the whole struct lives in
/// RAM; in a [`Spilled`] store only the stats do, and the edges stream
/// from the shard's file.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeShard {
    /// Canonical `(min, max)` edges owned by this shard: sorted, deduped,
    /// no self-loops, `machine_of(min) == shard index`.
    edges: Vec<(Vertex, Vertex)>,
    stats: ShardStats,
}

impl EdgeShard {
    /// Build from canonical edges (sorted, deduped, loop-free, owned by
    /// shard `s` of `p`).
    pub fn new_canonical(edges: Vec<(Vertex, Vertex)>, p: usize, s: usize) -> EdgeShard {
        let stats = ShardStats::from_edges(&edges, p, s);
        EdgeShard { edges, stats }
    }

    /// Rebuild from canonical edges whose statistics are already known —
    /// the un-spill path, where stats live in RAM while the edges come
    /// off a validated shard file.  Debug builds re-derive and compare.
    pub fn with_stats(
        edges: Vec<(Vertex, Vertex)>,
        stats: ShardStats,
        p: usize,
        s: usize,
    ) -> EdgeShard {
        debug_assert_eq!(stats, ShardStats::from_edges(&edges, p, s));
        let _ = (p, s);
        EdgeShard { edges, stats }
    }

    pub fn edges(&self) -> &[(Vertex, Vertex)] {
        &self.edges
    }

    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Per-machine ownership histogram of this shard's right endpoints.
    pub fn peer_counts(&self) -> &[u64] {
        &self.stats.peer_counts
    }

    pub fn stats(&self) -> &ShardStats {
        &self.stats
    }

    pub fn into_edges(self) -> Vec<(Vertex, Vertex)> {
        self.edges
    }
}

/// A view of one shard's edges: `Borrowed` from a resident store
/// (zero-copy slice), `Loaded` from the spill fallback path (owned, freed
/// when the view drops), or `Mapped` — a [`ShardCursor`] walking a
/// validated shard-file image in place (an mmap'd spill file or a
/// received wire frame; zero per-edge allocation).
///
/// Consumers iterate ([`ShardData::iter`] / `into_iter`) rather than
/// deref to a slice: a columnar image has no `&[(Vertex, Vertex)]` to
/// hand out, and that is the point.
#[derive(Debug)]
pub enum ShardData<'a> {
    Borrowed(&'a [(Vertex, Vertex)]),
    Loaded(Vec<(Vertex, Vertex)>),
    Mapped {
        cursor: ShardCursor<'a>,
        /// The full framed file image backing the cursor (header +
        /// columns + index) — transports ship these bytes verbatim, so a
        /// mapped shard goes on the wire without re-encoding.
        image: &'a [u8],
    },
}

impl<'a> ShardData<'a> {
    pub fn len(&self) -> usize {
        match self {
            ShardData::Borrowed(e) => e.len(),
            ShardData::Loaded(e) => e.len(),
            ShardData::Mapped { cursor, .. } => cursor.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrowing edge iterator (the view stays usable).
    pub fn iter(&self) -> ShardDataIter<'_> {
        match self {
            ShardData::Borrowed(e) => ShardDataIter::Borrowed(e.iter().copied()),
            ShardData::Loaded(e) => ShardDataIter::Borrowed(e.iter().copied()),
            ShardData::Mapped { cursor, .. } => ShardDataIter::Cursor(cursor.iter()),
        }
    }

    /// The complete framed file image, when this view is backed by one —
    /// the zero-copy source for shipping the shard on the wire.
    pub fn image(&self) -> Option<&'a [u8]> {
        match self {
            ShardData::Mapped { image, .. } => Some(image),
            _ => None,
        }
    }

    /// The contiguous row-major pairs, when the view borrows them from a
    /// resident store (`None` for owned or columnar-mapped views — those
    /// have no `&'a` slice to hand out).  Lets encoders avoid the
    /// [`into_vec`](Self::into_vec) copy on the resident path.
    pub fn as_pairs(&self) -> Option<&'a [(Vertex, Vertex)]> {
        match self {
            ShardData::Borrowed(e) => Some(e),
            _ => None,
        }
    }

    /// Consume into an owned edge vector (the rehydration escape hatch
    /// for paths that genuinely need a slice).
    pub fn into_vec(self) -> Vec<(Vertex, Vertex)> {
        match self {
            ShardData::Borrowed(e) => e.to_vec(),
            ShardData::Loaded(e) => e,
            ShardData::Mapped { cursor, .. } => cursor.iter().collect(),
        }
    }

    /// Consume into an iterator over rows `lo..hi` only — the sub-shard
    /// streaming primitive behind `ShardedGraph::msg_chunks_split`.
    /// Borrowed and mapped views slice for free; the owned fallback
    /// trims in place.
    pub fn into_range_iter(self, lo: usize, hi: usize) -> ShardDataIter<'a> {
        match self {
            ShardData::Borrowed(e) => ShardDataIter::Borrowed(e[lo..hi].iter().copied()),
            ShardData::Loaded(mut e) => {
                e.truncate(hi);
                drop(e.drain(..lo));
                ShardDataIter::Loaded(e.into_iter())
            }
            ShardData::Mapped { cursor, .. } => ShardDataIter::Cursor(cursor.slice(lo, hi).iter()),
        }
    }
}

/// Owning edge iterator over a [`ShardData`] view.
pub enum ShardDataIter<'a> {
    Borrowed(std::iter::Copied<std::slice::Iter<'a, (Vertex, Vertex)>>),
    Loaded(std::vec::IntoIter<(Vertex, Vertex)>),
    Cursor(CursorIter<'a>),
}

impl Iterator for ShardDataIter<'_> {
    type Item = (Vertex, Vertex);
    #[inline]
    fn next(&mut self) -> Option<(Vertex, Vertex)> {
        match self {
            ShardDataIter::Borrowed(it) => it.next(),
            ShardDataIter::Loaded(it) => it.next(),
            ShardDataIter::Cursor(it) => it.next(),
        }
    }
    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            ShardDataIter::Borrowed(it) => it.size_hint(),
            ShardDataIter::Loaded(it) => it.size_hint(),
            ShardDataIter::Cursor(it) => it.size_hint(),
        }
    }
}

impl ExactSizeIterator for ShardDataIter<'_> {}

impl<'a> IntoIterator for ShardData<'a> {
    type Item = (Vertex, Vertex);
    type IntoIter = ShardDataIter<'a>;
    fn into_iter(self) -> ShardDataIter<'a> {
        match self {
            ShardData::Borrowed(e) => ShardDataIter::Borrowed(e.iter().copied()),
            ShardData::Loaded(e) => ShardDataIter::Loaded(e.into_iter()),
            ShardData::Mapped { cursor, .. } => ShardDataIter::Cursor(cursor.iter()),
        }
    }
}

// ---------------------------------------------------------------------------
// zero-copy cursor over a shard image

#[derive(Debug, Clone)]
enum CursorKind<'a> {
    /// Legacy `LCCSHRD1` payload: `m × (src u32, dst u32)` row-major LE.
    Rows { pairs: &'a [u8] },
    /// Columnar `LCCSHRD2` payload: split src/dst columns plus the
    /// optional bucket index over the sorted src column.
    Columns {
        src: &'a [u8],
        dst: &'a [u8],
        /// `(B+1) × u64 LE` bucket offsets; empty when the image carries
        /// no index (empty shard, unsorted payload, or a sliced cursor).
        index: &'a [u8],
        span: u32,
    },
}

/// Borrowed walk of one shard image — the working representation of a
/// spilled or wire-received shard.  All reads go through `from_le_bytes`
/// on byte slices, so the backing image needs no alignment: an mmap'd
/// file, a frame body at an arbitrary offset inside a receive buffer,
/// and an owned fallback copy parse identically, and iteration performs
/// zero heap allocation.
#[derive(Debug, Clone)]
pub struct ShardCursor<'a> {
    kind: CursorKind<'a>,
    len: usize,
}

#[inline]
fn le_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
}

#[inline]
fn le_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

impl<'a> ShardCursor<'a> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The edge at row `i`.
    #[inline]
    pub fn get(&self, i: usize) -> (Vertex, Vertex) {
        debug_assert!(i < self.len);
        match &self.kind {
            CursorKind::Rows { pairs } => {
                let off = i * PAIR_BYTES as usize;
                (le_u32(pairs, off), le_u32(pairs, off + 4))
            }
            CursorKind::Columns { src, dst, .. } => (le_u32(src, i * 4), le_u32(dst, i * 4)),
        }
    }

    #[inline]
    fn src_at(&self, i: usize) -> Vertex {
        match &self.kind {
            CursorKind::Rows { pairs } => le_u32(pairs, i * PAIR_BYTES as usize),
            CursorKind::Columns { src, .. } => le_u32(src, i * 4),
        }
    }

    pub fn iter(&self) -> CursorIter<'a> {
        CursorIter {
            cursor: self.clone(),
            pos: 0,
            end: self.len,
        }
    }

    /// Sub-cursor over rows `lo..hi` — the per-thread sub-shard view.
    /// The absolute bucket index does not survive slicing, so sliced
    /// cursors answer [`ShardCursor::vertex_range`] by plain binary
    /// search over their (still sorted) sub-range.
    pub fn slice(&self, lo: usize, hi: usize) -> ShardCursor<'a> {
        assert!(lo <= hi && hi <= self.len);
        let kind = match &self.kind {
            CursorKind::Rows { pairs } => CursorKind::Rows {
                pairs: &pairs[lo * PAIR_BYTES as usize..hi * PAIR_BYTES as usize],
            },
            CursorKind::Columns { src, dst, .. } => CursorKind::Columns {
                src: &src[lo * 4..hi * 4],
                dst: &dst[lo * 4..hi * 4],
                index: &[],
                span: 0,
            },
        };
        ShardCursor { kind, len: hi - lo }
    }

    /// The row range holding every edge with source `v` (empty when
    /// none).  Bucketed O(1)+O(log(m/B)) on indexed columnar images,
    /// plain binary search otherwise — both require the canonical shard
    /// invariant (sorted by `(src, dst)`), which every shard file and
    /// frame in the engine satisfies.  This is the touched-range
    /// streaming entry point: hop generators that only need a vertex
    /// neighborhood read just these rows, not the shard.
    pub fn vertex_range(&self, v: Vertex) -> std::ops::Range<usize> {
        let (mut lo, mut hi) = (0usize, self.len);
        if let CursorKind::Columns { index, span, .. } = &self.kind {
            if !index.is_empty() {
                let buckets = (index.len() / 8 - 1) as u64;
                let b = index_bucket(v, buckets, *span);
                lo = le_u64(index, b * 8) as usize;
                hi = le_u64(index, (b + 1) * 8) as usize;
            }
        }
        let start = self.partition(lo, hi, |s| s < v);
        let end = self.partition(start, hi, |s| s <= v);
        start..end
    }

    /// First row in `lo..hi` whose src fails `pred` (binary search; `pred`
    /// must be monotone over the sorted src column).
    fn partition(&self, mut lo: usize, mut hi: usize, pred: impl Fn(Vertex) -> bool) -> usize {
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if pred(self.src_at(mid)) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// Allocation-free edge iterator over a [`ShardCursor`].
#[derive(Debug, Clone)]
pub struct CursorIter<'a> {
    cursor: ShardCursor<'a>,
    pos: usize,
    end: usize,
}

impl Iterator for CursorIter<'_> {
    type Item = (Vertex, Vertex);
    #[inline]
    fn next(&mut self) -> Option<(Vertex, Vertex)> {
        if self.pos < self.end {
            let e = self.cursor.get(self.pos);
            self.pos += 1;
            Some(e)
        } else {
            None
        }
    }
    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.end - self.pos;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for CursorIter<'_> {}

// ---------------------------------------------------------------------------
// data-plane counters

static SHARD_BYTES_MAPPED: AtomicU64 = AtomicU64::new(0);
static SHARD_BYTES_COPIED: AtomicU64 = AtomicU64::new(0);
static SHARD_MAPS: AtomicU64 = AtomicU64::new(0);
static SHARD_COPIES: AtomicU64 = AtomicU64::new(0);

/// Process-wide spilled-shard load accounting: how many shard file
/// images were mmap'd in place vs read through the owned-copy fallback.
/// Steady state on a healthy unix host is `shard_copies == 0` — CI
/// asserts exactly that on the spill job, so a silent regression to the
/// copy path fails the gate instead of just running slower.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DataPlaneCounters {
    pub shard_bytes_mapped: u64,
    pub shard_bytes_copied: u64,
    pub shard_maps: u64,
    pub shard_copies: u64,
}

/// Snapshot the process-wide data-plane counters.
pub fn data_plane_counters() -> DataPlaneCounters {
    DataPlaneCounters {
        shard_bytes_mapped: SHARD_BYTES_MAPPED.load(Ordering::Relaxed),
        shard_bytes_copied: SHARD_BYTES_COPIED.load(Ordering::Relaxed),
        shard_maps: SHARD_MAPS.load(Ordering::Relaxed),
        shard_copies: SHARD_COPIES.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// mmap backing

#[cfg(unix)]
mod mmap {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    // Minimal raw bindings: std already links libc on unix and the crate
    // adds no dependencies, so declare exactly the two symbols we need.
    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// A read-only private mapping of a whole file.  The pages are clean
    /// page cache: the kernel evicts cold shards under memory pressure
    /// and faults them back on demand, which is what makes a mapped
    /// spill read cheaper than an owned buffer of the same size.
    pub struct Mmap {
        ptr: *mut core::ffi::c_void,
        len: usize,
    }

    // PROT_READ for the mapping's whole lifetime: immutable shared bytes.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn map(file: &File, len: usize) -> std::io::Result<Mmap> {
            if len == 0 {
                // zero-length mmap is EINVAL; an empty file needs no pages
                return Ok(Mmap {
                    ptr: std::ptr::null_mut(),
                    len: 0,
                });
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Mmap { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            if self.len == 0 {
                &[]
            } else {
                unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
            }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            if self.len != 0 {
                unsafe {
                    munmap(self.ptr, self.len);
                }
            }
        }
    }

    impl std::fmt::Debug for Mmap {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Mmap").field("len", &self.len).finish()
        }
    }
}

/// The backing bytes of one shard-file image: a live mapping on unix, an
/// owned copy on the fallback path (non-unix targets, or a host whose
/// filesystem refuses `mmap`).
#[derive(Debug)]
pub enum ShardImage {
    #[cfg(unix)]
    Mapped(mmap::Mmap),
    Owned(Vec<u8>),
}

impl ShardImage {
    pub fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            ShardImage::Mapped(m) => m.as_slice(),
            ShardImage::Owned(v) => v,
        }
    }
}

/// Map (or, failing that, copy) a whole shard file into a [`ShardImage`],
/// charging the data-plane counters.
fn load_shard_image(path: &Path) -> Result<ShardImage, SpillError> {
    let bytes_via_copy = |path: &Path| -> Result<Vec<u8>, SpillError> {
        fs::read(path).map_err(|e| SpillError::io(path, "read", e))
    };
    #[cfg(unix)]
    {
        let f = File::open(path).map_err(|e| SpillError::io(path, "open", e))?;
        let len = f
            .metadata()
            .map_err(|e| SpillError::io(path, "stat", e))?
            .len();
        let len = usize::try_from(len).map_err(|_| SpillError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("file length {len} exceeds the address space"),
        })?;
        match mmap::Mmap::map(&f, len) {
            Ok(m) => {
                SHARD_MAPS.fetch_add(1, Ordering::Relaxed);
                SHARD_BYTES_MAPPED.fetch_add(len as u64, Ordering::Relaxed);
                Ok(ShardImage::Mapped(m))
            }
            // exotic filesystems can refuse mmap; stay correct (and
            // visibly slower in the counters) rather than fail the run
            Err(_) => {
                let v = bytes_via_copy(path)?;
                SHARD_COPIES.fetch_add(1, Ordering::Relaxed);
                SHARD_BYTES_COPIED.fetch_add(v.len() as u64, Ordering::Relaxed);
                Ok(ShardImage::Owned(v))
            }
        }
    }
    #[cfg(not(unix))]
    {
        let v = bytes_via_copy(path)?;
        SHARD_COPIES.fetch_add(1, Ordering::Relaxed);
        SHARD_BYTES_COPIED.fetch_add(v.len() as u64, Ordering::Relaxed);
        Ok(ShardImage::Owned(v))
    }
}

// ---------------------------------------------------------------------------
// residency policy

/// When to trade RAM for disk.
#[derive(Debug, Clone, Default)]
pub struct SpillPolicy {
    /// Maximum bytes of resident edge data per graph; edge sets larger
    /// than this live on disk.  `None` = unbounded (always resident).
    pub budget_bytes: Option<u64>,
    /// Root directory for spill files (default: the OS temp dir).  Each
    /// graph generation gets its own subdirectory, removed when the last
    /// clone of the graph drops.
    pub root: Option<PathBuf>,
}

impl SpillPolicy {
    /// Unbounded: never spill (the default, and the PR 2 behavior).
    pub fn unbounded() -> SpillPolicy {
        SpillPolicy::default()
    }

    /// Spill whenever resident edge bytes would exceed `bytes`.
    pub fn budget(bytes: u64) -> SpillPolicy {
        SpillPolicy {
            budget_bytes: Some(bytes),
            root: None,
        }
    }

    /// From an optional budget (the `MpcConfig::spill_budget` /
    /// `--spill-budget` plumbing shape).
    pub fn with_budget(budget: Option<u64>) -> SpillPolicy {
        SpillPolicy {
            budget_bytes: budget,
            root: None,
        }
    }

    /// Should a graph of `edge_bytes` resident bytes spill?
    pub fn should_spill(&self, edge_bytes: u64) -> bool {
        self.budget_bytes.map_or(false, |b| edge_bytes > b)
    }
}

// ---------------------------------------------------------------------------
// spill directories

/// A spill directory owned by one graph generation.  Created uniquely
/// under the policy root; removed (with its files) on drop — except for
/// adopted directories (persisted spills opened via
/// `ShardedGraph::open_spilled`), which belong to the user.
#[derive(Debug)]
pub struct SpillDir {
    path: PathBuf,
    owned: bool,
}

static DIR_COUNTER: AtomicU64 = AtomicU64::new(0);

impl SpillDir {
    /// Create a fresh uniquely-named directory under `root` (OS temp dir
    /// when `None`).
    pub fn create_temp(root: Option<&Path>) -> Result<SpillDir, SpillError> {
        let base = root
            .map(Path::to_path_buf)
            .unwrap_or_else(std::env::temp_dir);
        let path = base.join(format!(
            "lcc-spill-{}-{}",
            std::process::id(),
            DIR_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&path).map_err(|e| SpillError::io(&path, "create dir", e))?;
        Ok(SpillDir { path, owned: true })
    }

    /// Wrap an existing user-owned directory (not removed on drop).
    pub fn adopt(path: PathBuf) -> SpillDir {
        SpillDir { path, owned: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        if self.owned {
            let _ = fs::remove_dir_all(&self.path);
        }
    }
}

// ---------------------------------------------------------------------------
// shard file framing

/// Index layout of a shard about to be encoded: bucket count (0 when the
/// payload is empty or not sorted by src — the index requires the
/// canonical sort) and the span `max(src) + 1` (saturating).
fn index_plan(edges: &[(Vertex, Vertex)]) -> (u64, u32) {
    if edges.is_empty() {
        return (0, 0);
    }
    let mut max_src = 0u32;
    let mut prev = 0u32;
    let mut sorted = true;
    for (i, &(u, _)) in edges.iter().enumerate() {
        if i > 0 && u < prev {
            sorted = false;
        }
        prev = u;
        max_src = max_src.max(u);
    }
    let span = max_src.saturating_add(1);
    if sorted {
        (index_buckets(edges.len() as u64), span)
    } else {
        (0, span)
    }
}

/// Bucket offsets (`B+1` entries, `offs[0] == 0`, `offs[B] == m`) of the
/// sorted src column under ([`index_bucket`], `span`).
fn build_index(edges: &[(Vertex, Vertex)], buckets: u64, span: u32) -> Vec<u64> {
    let mut offs = vec![0u64; buckets as usize + 1];
    for &(u, _) in edges {
        offs[index_bucket(u, buckets, span) + 1] += 1;
    }
    for i in 1..offs.len() {
        offs[i] += offs[i - 1];
    }
    offs
}

/// Encode one shard's canonical edges as a complete columnar shard-file
/// image (header + src/dst columns + index) in memory, returning the
/// bytes and the logical payload checksum.  This is the **shard wire
/// format**: [`write_shard_file`] writes exactly these bytes, and the
/// multi-process transport (`crate::mpc::net`) ships them verbatim when
/// distributing shards to worker processes — a spilled shard file goes on
/// the wire without rehydration, and a resident shard serializes
/// identically.
pub fn encode_shard_bytes(
    shard: u32,
    num_shards: u32,
    edges: &[(Vertex, Vertex)],
) -> (Vec<u8>, u64) {
    let checksum = checksum_edges(edges);
    let (buckets, span) = index_plan(edges);
    let index_bytes = if buckets > 0 { (buckets as usize + 1) * 8 } else { 0 };
    let mut out = Vec::with_capacity(
        V2_HEADER_BYTES as usize + edges.len() * PAIR_BYTES as usize + index_bytes,
    );
    out.extend_from_slice(SHARD_MAGIC_V2);
    out.extend_from_slice(&shard.to_le_bytes());
    out.extend_from_slice(&num_shards.to_le_bytes());
    out.extend_from_slice(&(edges.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum.to_le_bytes());
    out.extend_from_slice(&(buckets as u32).to_le_bytes());
    out.extend_from_slice(&span.to_le_bytes());
    for &(u, _) in edges {
        out.extend_from_slice(&u.to_le_bytes());
    }
    for &(_, v) in edges {
        out.extend_from_slice(&v.to_le_bytes());
    }
    if buckets > 0 {
        for off in build_index(edges, buckets, span) {
            out.extend_from_slice(&off.to_le_bytes());
        }
    }
    (out, checksum)
}

/// Write one shard's canonical edges as a checksummed shard file —
/// streamed through a [`BufWriter`], byte-identical to
/// [`encode_shard_bytes`] (spilling runs exactly when memory is tight,
/// so the file path must not materialize a second copy of the shard).
/// Returns the payload checksum (recorded in manifests).
pub fn write_shard_file(
    path: &Path,
    shard: u32,
    num_shards: u32,
    edges: &[(Vertex, Vertex)],
) -> Result<u64, SpillError> {
    let f = File::create(path).map_err(|e| SpillError::io(path, "create", e))?;
    let mut w = BufWriter::new(f);
    let checksum = checksum_edges(edges);
    let (buckets, span) = index_plan(edges);
    let write = |w: &mut BufWriter<File>| -> std::io::Result<()> {
        w.write_all(SHARD_MAGIC_V2)?;
        w.write_all(&shard.to_le_bytes())?;
        w.write_all(&num_shards.to_le_bytes())?;
        w.write_all(&(edges.len() as u64).to_le_bytes())?;
        w.write_all(&checksum.to_le_bytes())?;
        w.write_all(&(buckets as u32).to_le_bytes())?;
        w.write_all(&span.to_le_bytes())?;
        for &(u, _) in edges {
            w.write_all(&u.to_le_bytes())?;
        }
        for &(_, v) in edges {
            w.write_all(&v.to_le_bytes())?;
        }
        if buckets > 0 {
            for off in build_index(edges, buckets, span) {
                w.write_all(&off.to_le_bytes())?;
            }
        }
        w.flush()
    };
    write(&mut w).map_err(|e| SpillError::io(path, "write", e))?;
    Ok(checksum)
}

/// The exact image length of a well-formed shard of `m` edges in the
/// given framing (`None` on arithmetic overflow — a lying header).
fn expected_image_len(v2: bool, m: u64, buckets: u64) -> Option<u64> {
    let payload = m.checked_mul(PAIR_BYTES)?;
    if v2 {
        let index = if buckets > 0 {
            buckets.checked_add(1)?.checked_mul(8)?
        } else {
            0
        };
        payload
            .checked_add(V2_HEADER_BYTES)?
            .checked_add(index)
    } else {
        payload.checked_add(SHARD_HEADER_BYTES)
    }
}

/// Check a shard file's header-claimed size against the actual file
/// length without reading the payload (the cheap validation
/// `ShardedGraph::open_spilled` runs eagerly per shard).  Peeks the magic
/// to pick the framing: canonical columnar files carry the deterministic
/// `min(m, 4096)`-bucket index, legacy row-major files carry none.
pub fn validate_shard_file_len(path: &Path, expected_edges: u64) -> Result<(), SpillError> {
    let mut magic = [0u8; 8];
    let mut f = File::open(path).map_err(|e| SpillError::io(path, "open", e))?;
    let actual = f
        .metadata()
        .map_err(|e| SpillError::io(path, "stat", e))?
        .len();
    if actual < 8 {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: V2_HEADER_BYTES,
            actual_bytes: actual,
        });
    }
    f.read_exact(&mut magic)
        .map_err(|e| SpillError::io(path, "read", e))?;
    let v2 = match &magic {
        m if m == SHARD_MAGIC_V2 => true,
        m if m == SHARD_MAGIC => false,
        _ => {
            return Err(SpillError::BadMagic {
                path: path.to_path_buf(),
            })
        }
    };
    let expected = expected_image_len(v2, expected_edges, index_buckets(expected_edges))
        .ok_or_else(|| SpillError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("edge count {expected_edges} overflows the file length"),
        })?;
    if actual != expected {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: expected,
            actual_bytes: actual,
        });
    }
    Ok(())
}

/// Parse one shard image's header without walking the payload: magic
/// (both framings), shard identity, declared counts vs the actual image
/// length — **before any allocation**, so a lying header cannot drive a
/// reservation.  Returns the borrowed cursor plus the header-declared
/// (not yet verified) checksum.  This is the cheap re-parse used on
/// images already validated once ([`parse_shard_image`] for the full
/// walk).  `origin` names the byte source in errors (a file path, or a
/// synthetic name like `<frame>` for transport traffic).
pub fn parse_shard_header<'a>(
    bytes: &'a [u8],
    shard: u32,
    num_shards: u32,
    origin: &Path,
) -> Result<(ShardCursor<'a>, u64), SpillError> {
    let actual_len = bytes.len() as u64;
    let truncated = |expected: u64| SpillError::Truncated {
        path: origin.to_path_buf(),
        expected_bytes: expected,
        actual_bytes: actual_len,
    };
    if actual_len < 8 {
        return Err(truncated(V2_HEADER_BYTES));
    }
    let v2 = match &bytes[..8] {
        m if m == SHARD_MAGIC_V2 => true,
        m if m == SHARD_MAGIC => false,
        _ => {
            return Err(SpillError::BadMagic {
                path: origin.to_path_buf(),
            })
        }
    };
    let header = if v2 { V2_HEADER_BYTES } else { SHARD_HEADER_BYTES };
    if actual_len < header {
        return Err(truncated(header));
    }
    let got_shard = le_u32(bytes, 8);
    let got_p = le_u32(bytes, 12);
    if (got_shard, got_p) != (shard, num_shards) {
        return Err(SpillError::Corrupt {
            path: origin.to_path_buf(),
            detail: format!(
                "file is shard {got_shard}/{got_p}, store expected {shard}/{num_shards}"
            ),
        });
    }
    let m = le_u64(bytes, 16);
    let declared_checksum = le_u64(bytes, 24);
    let buckets = if v2 { le_u32(bytes, 32) as u64 } else { 0 };
    let span = if v2 { le_u32(bytes, 36) } else { 0 };
    if v2 && buckets != 0 && buckets != index_buckets(m) {
        return Err(SpillError::Corrupt {
            path: origin.to_path_buf(),
            detail: format!(
                "index declares {buckets} buckets; a shard of {m} edges has {} or none",
                index_buckets(m)
            ),
        });
    }
    // validate the claimed count against the actual length BEFORE
    // trusting any derived offset
    match expected_image_len(v2, m, buckets) {
        Some(expected) if expected == actual_len => {}
        other => return Err(truncated(other.unwrap_or(u64::MAX))),
    }
    let len = m as usize;
    let kind = if v2 {
        let cols = V2_HEADER_BYTES as usize;
        CursorKind::Columns {
            src: &bytes[cols..cols + len * 4],
            dst: &bytes[cols + len * 4..cols + len * 8],
            index: &bytes[cols + len * 8..],
            span,
        }
    } else {
        CursorKind::Rows {
            pairs: &bytes[SHARD_HEADER_BYTES as usize..],
        }
    };
    Ok((ShardCursor { kind, len }, declared_checksum))
}

/// Parse and **fully validate** one shard image: everything
/// [`parse_shard_header`] checks, then one walk of the payload verifying
/// the declared checksum and — because the logical checksum does not
/// cover the index bytes — rebuilding the expected bucket offsets from
/// the src column and comparing them to the stored index.  Returns the
/// borrowed cursor plus the verified payload checksum.
///
/// This is the read half of the shard wire format
/// ([`encode_shard_bytes`]): shard files on disk and shards shipped to
/// worker processes validate through the same code.
pub fn parse_shard_image<'a>(
    bytes: &'a [u8],
    shard: u32,
    num_shards: u32,
    origin: &Path,
) -> Result<(ShardCursor<'a>, u64), SpillError> {
    let (cursor, declared) = parse_shard_header(bytes, shard, num_shards, origin)?;
    let corrupt = |detail: String| SpillError::Corrupt {
        path: origin.to_path_buf(),
        detail,
    };
    let span = match &cursor.kind {
        CursorKind::Columns { span, .. } => *span,
        CursorKind::Rows { .. } => 0,
    };
    let mut counts: Vec<u64> = match &cursor.kind {
        CursorKind::Columns { index, .. } if !index.is_empty() => vec![0u64; index.len() / 8 - 1],
        _ => Vec::new(),
    };
    let mut h = Fnv1a::new();
    let mut prev_src = 0u32;
    let mut sorted = true;
    for i in 0..cursor.len {
        let (u, v) = cursor.get(i);
        h.update(&u.to_le_bytes());
        h.update(&v.to_le_bytes());
        if i > 0 && u < prev_src {
            sorted = false;
        }
        prev_src = u;
        if !counts.is_empty() {
            counts[index_bucket(u, counts.len() as u64, span)] += 1;
        }
    }
    let actual = h.finish();
    if actual != declared {
        return Err(SpillError::ChecksumMismatch {
            path: origin.to_path_buf(),
            expected: declared,
            actual,
        });
    }
    if let CursorKind::Columns { index, .. } = &cursor.kind {
        if !index.is_empty() {
            // the index is only meaningful over a sorted src column
            if !sorted {
                return Err(corrupt("indexed image's src column is not sorted".into()));
            }
            let mut running = 0u64;
            if le_u64(index, 0) != 0 {
                return Err(corrupt("index bucket 0 does not start at row 0".into()));
            }
            for (b, &c) in counts.iter().enumerate() {
                running += c;
                let stored = le_u64(index, (b + 1) * 8);
                if stored != running {
                    return Err(corrupt(format!(
                        "index bucket {b} ends at row {stored}, src column says {running}"
                    )));
                }
            }
        }
    }
    Ok((cursor, actual))
}

/// Parse, fully validate, and rehydrate one shard image into an owned
/// edge vector (see [`parse_shard_image`] for the checks; the allocation
/// is bounded by the *validated* image length, never by the header).
/// The escape hatch for consumers that need owned pairs — the engine's
/// round paths walk the cursor in place instead.
pub fn read_shard_bytes(
    bytes: &[u8],
    shard: u32,
    num_shards: u32,
    origin: &Path,
) -> Result<(Vec<(Vertex, Vertex)>, u64), SpillError> {
    let (cursor, checksum) = parse_shard_image(bytes, shard, num_shards, origin)?;
    Ok((cursor.iter().collect(), checksum))
}

thread_local! {
    /// Per-worker reusable file-image buffer for spilled shard loads.
    /// Every pool worker streams one shard at a time (the residency
    /// invariant), so one buffer per thread turns the per-load file-image
    /// allocation + 8-byte-at-a-time `read_exact` loop into a single
    /// bulk read into warm memory; only the returned edge vector is
    /// allocated fresh.  §Perf: measured by the spilled `lcc perf` rows.
    static READ_BUF: std::cell::RefCell<Vec<u8>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Retained capacity cap for [`READ_BUF`]: reuse serves the per-round
/// load loop, not a permanent high-water reservation — a one-off giant
/// shard must not pin `threads × shard` bytes for the process lifetime
/// (spilling runs exactly when memory is tight).
const READ_BUF_RETAIN: usize = 8 << 20;

fn trim_read_buf(buf: &mut Vec<u8>) {
    if buf.capacity() > READ_BUF_RETAIN {
        buf.clear();
        buf.shrink_to(READ_BUF_RETAIN);
    }
}

/// Read a whole file into the thread-local reuse buffer.
fn read_file_reusing(path: &Path, buf: &mut Vec<u8>) -> Result<(), SpillError> {
    let mut f = File::open(path).map_err(|e| SpillError::io(path, "open", e))?;
    let len = f
        .metadata()
        .map_err(|e| SpillError::io(path, "stat", e))?
        .len();
    buf.clear();
    buf.reserve(len as usize);
    f.read_to_end(buf)
        .map_err(|e| SpillError::io(path, "read", e))?;
    Ok(())
}

/// Read and fully validate one shard file (see [`read_shard_bytes`] for
/// the checks).  The file image lands in the calling worker's reusable
/// read buffer; only the decoded edges are freshly allocated.
pub fn read_shard_file(
    path: &Path,
    shard: u32,
    num_shards: u32,
) -> Result<(Vec<(Vertex, Vertex)>, u64), SpillError> {
    READ_BUF.with(|b| {
        let mut buf = b.borrow_mut();
        read_file_reusing(path, &mut buf)?;
        let result = read_shard_bytes(&buf, shard, num_shards, path);
        trim_read_buf(&mut buf);
        result
    })
}

/// Read an unframed staging file of raw pairs (`len` from a prior stat —
/// transient rewrite intermediates, no checksum).  Shares the per-worker
/// read buffer with [`read_shard_file`].
pub fn read_raw_pairs(path: &Path, len: u64) -> Result<Vec<(Vertex, Vertex)>, SpillError> {
    if len % PAIR_BYTES != 0 {
        return Err(SpillError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("staging length {len} is not a multiple of {PAIR_BYTES}"),
        });
    }
    READ_BUF.with(|b| {
        let mut buf = b.borrow_mut();
        read_file_reusing(path, &mut buf)?;
        let result = if buf.len() as u64 != len {
            Err(SpillError::Truncated {
                path: path.to_path_buf(),
                expected_bytes: len,
                actual_bytes: buf.len() as u64,
            })
        } else {
            Ok(crate::graph::io::decode_pairs(&buf))
        };
        trim_read_buf(&mut buf);
        result
    })
}

// ---------------------------------------------------------------------------
// the store abstraction

/// Shard storage backend: uniform access to shard statistics (always in
/// RAM) and shard edges (in RAM or streamed from disk).
pub trait ShardStore {
    fn num_shards(&self) -> usize;

    /// Cached statistics of shard `s` — never touches disk.
    fn stats(&self, s: usize) -> &ShardStats;

    /// The edges of shard `s`: borrowed from a resident store, loaded and
    /// validated from a spilled one.
    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError>;

    fn is_spilled(&self) -> bool;
}

/// All shards in RAM (the fast path).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Resident {
    shards: Vec<EdgeShard>,
}

impl Resident {
    pub fn new(shards: Vec<EdgeShard>) -> Resident {
        Resident { shards }
    }

    pub fn shards(&self) -> &[EdgeShard] {
        &self.shards
    }

    pub fn into_shards(self) -> Vec<EdgeShard> {
        self.shards
    }
}

impl ShardStore for Resident {
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn stats(&self, s: usize) -> &ShardStats {
        self.shards[s].stats()
    }

    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError> {
        Ok(ShardData::Borrowed(self.shards[s].edges()))
    }

    fn is_spilled(&self) -> bool {
        false
    }
}

/// Metadata of one spilled shard (the RAM footprint of the shard), plus
/// the lazily-established mapping of its file image.
#[derive(Debug)]
pub struct SpilledShard {
    pub path: PathBuf,
    pub stats: ShardStats,
    pub checksum: u64,
    /// The shard's file image, mapped and fully validated on first read
    /// (checksum walk + index verification happen once per generation —
    /// shard files are immutable once written); later reads re-parse only
    /// the header.  Mapped pages are clean page cache, so the RAM cost of
    /// keeping this "cached" is whatever the kernel decides is warm.
    image: std::sync::OnceLock<ShardImage>,
}

impl SpilledShard {
    pub fn new(path: PathBuf, stats: ShardStats, checksum: u64) -> SpilledShard {
        SpilledShard {
            path,
            stats,
            checksum,
            image: std::sync::OnceLock::new(),
        }
    }
}

impl Clone for SpilledShard {
    fn clone(&self) -> SpilledShard {
        // the mapping is not shared across clones: each clone re-maps
        // (and re-validates) lazily, keeping clone cheap and `Drop` exact
        SpilledShard::new(self.path.clone(), self.stats.clone(), self.checksum)
    }
}

/// All shards on disk; clones share the directory via `Arc` (shard files
/// are immutable once written — every mutation builds a new generation).
#[derive(Debug, Clone)]
pub struct Spilled {
    dir: std::sync::Arc<SpillDir>,
    shards: Vec<SpilledShard>,
}

impl Spilled {
    pub fn from_parts(dir: std::sync::Arc<SpillDir>, shards: Vec<SpilledShard>) -> Spilled {
        Spilled { dir, shards }
    }

    pub fn dir(&self) -> &Path {
        self.dir.path()
    }

    /// RAM-cached per-shard metadata (stats + payload checksums).
    pub fn shard_metas(&self) -> &[SpilledShard] {
        &self.shards
    }
}

impl ShardStore for Spilled {
    fn num_shards(&self) -> usize {
        self.shards.len()
    }

    fn stats(&self, s: usize) -> &ShardStats {
        &self.shards[s].stats
    }

    fn read(&self, s: usize) -> Result<ShardData<'_>, SpillError> {
        let meta = &self.shards[s];
        let num_shards = self.shards.len() as u32;
        if meta.image.get().is_none() {
            // first touch of this generation: map the file and pay the one
            // full validation walk (header, payload checksum, index)
            let image = load_shard_image(&meta.path)?;
            let (cursor, checksum) =
                parse_shard_image(image.bytes(), s as u32, num_shards, &meta.path)?;
            if cursor.len() as u64 != meta.stats.len {
                return Err(SpillError::Corrupt {
                    path: meta.path.clone(),
                    detail: format!(
                        "file holds {} edges, store expected {}",
                        cursor.len(),
                        meta.stats.len
                    ),
                });
            }
            // the file's header checksum only proves self-consistency; the
            // store's cached checksum pins the *generation* — a stale but
            // intact file (e.g. an interrupted re-persist) must not be read
            // as if it matched the RAM-cached stats
            if checksum != meta.checksum {
                return Err(SpillError::ChecksumMismatch {
                    path: meta.path.clone(),
                    expected: meta.checksum,
                    actual: checksum,
                });
            }
            // benign race: if two threads validated concurrently, the
            // loser's mapping is simply dropped (unmapped) here
            let _ = meta.image.set(image);
        }
        let image = meta.image.get().expect("image cached above").bytes();
        // already validated once for this generation: the cheap header
        // re-parse only re-derives the borrowed column bounds
        let (cursor, _) = parse_shard_header(image, s as u32, num_shards, &meta.path)?;
        Ok(ShardData::Mapped { cursor, image })
    }

    fn is_spilled(&self) -> bool {
        true
    }
}

/// Write one finalized shard into `dir`, returning its spilled metadata.
pub fn spill_shard(
    dir: &SpillDir,
    s: usize,
    num_shards: usize,
    shard: &EdgeShard,
) -> Result<SpilledShard, SpillError> {
    let path = dir.path().join(shard_file_name(s));
    let checksum = write_shard_file(&path, s as u32, num_shards as u32, shard.edges())?;
    Ok(SpilledShard::new(path, shard.stats().clone(), checksum))
}

// ---------------------------------------------------------------------------
// persisted-spill manifest (crash-then-reload)

/// Per-shard manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestShard {
    pub len: u64,
    pub checksum: u64,
    pub peer_counts: Vec<u64>,
}

/// Manifest of a persisted spilled graph: enough to rebuild the store's
/// RAM-cached state without reading any shard payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub n: u64,
    pub p: u32,
    pub shards: Vec<ManifestShard>,
}

/// Crash-consistent file replacement: write the full image to a sibling
/// `.tmp` file, fsync it, then atomically rename over `path`.  A crash at
/// any point leaves either the old file intact or the new one complete —
/// never a torn mix — which is what lets the manifest double as a
/// recovery checkpoint.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), SpillError> {
    let tmp = match (path.parent(), path.file_name()) {
        (Some(dir), Some(name)) => {
            let mut t = name.to_os_string();
            t.push(".tmp");
            dir.join(t)
        }
        _ => {
            return Err(SpillError::Corrupt {
                path: path.to_path_buf(),
                detail: "atomic write target has no parent directory".into(),
            })
        }
    };
    let write = || -> std::io::Result<()> {
        let f = File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        w.write_all(bytes)?;
        w.flush()?;
        // fsync before the rename: the rename must never become durable
        // ahead of the data it points at
        w.get_ref().sync_all()
    };
    write().map_err(|e| SpillError::io(&tmp, "write", e))?;
    fs::rename(&tmp, path).map_err(|e| SpillError::io(path, "rename", e))
}

/// Serialize + write a manifest (body FNV-checksummed like the shards),
/// via tmp-write + fsync + atomic rename: a crash mid-write can never
/// leave a torn manifest in place of a valid one.
pub fn write_manifest(path: &Path, m: &Manifest) -> Result<(), SpillError> {
    let mut body: Vec<u8> = Vec::new();
    body.extend_from_slice(&m.n.to_le_bytes());
    body.extend_from_slice(&m.p.to_le_bytes());
    for sh in &m.shards {
        body.extend_from_slice(&sh.len.to_le_bytes());
        body.extend_from_slice(&sh.checksum.to_le_bytes());
        for &c in &sh.peer_counts {
            body.extend_from_slice(&c.to_le_bytes());
        }
    }
    let mut h = Fnv1a::new();
    h.update(&body);
    let h = h.finish();
    let mut image = Vec::with_capacity(8 + body.len() + 8);
    image.extend_from_slice(MANIFEST_MAGIC);
    image.extend_from_slice(&body);
    image.extend_from_slice(&h.to_le_bytes());
    write_atomic(path, &image)
}

/// Read + validate a manifest (magic, exact length, body checksum).
pub fn read_manifest(path: &Path) -> Result<Manifest, SpillError> {
    let bytes = fs::read(path).map_err(|e| SpillError::io(path, "read", e))?;
    let corrupt = |detail: String| SpillError::Corrupt {
        path: path.to_path_buf(),
        detail,
    };
    if bytes.len() < 8 + 8 + 4 + 8 {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: (8 + 8 + 4 + 8) as u64,
            actual_bytes: bytes.len() as u64,
        });
    }
    if &bytes[..8] != MANIFEST_MAGIC {
        return Err(SpillError::BadMagic {
            path: path.to_path_buf(),
        });
    }
    let body = &bytes[8..bytes.len() - 8];
    let mut fnv = Fnv1a::new();
    fnv.update(body);
    let h = fnv.finish();
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if h != stored {
        return Err(SpillError::ChecksumMismatch {
            path: path.to_path_buf(),
            expected: stored,
            actual: h,
        });
    }
    let u64_at = |off: usize| -> u64 { u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) };
    let n = u64_at(0);
    let p = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
    // file-supplied p: checked arithmetic so a garbage count is a typed
    // Corrupt, not a debug-build overflow panic
    let per_shard = 8 + 8 + 8 * p; // p <= u32::MAX, so this term cannot overflow u64-sized usize
    per_shard
        .checked_mul(p)
        .and_then(|b| b.checked_add(12))
        .filter(|&b| b == body.len())
        .ok_or_else(|| {
            corrupt(format!(
                "manifest body is {} bytes, inconsistent with p={p}",
                body.len()
            ))
        })?;
    let mut shards = Vec::with_capacity(p);
    for s in 0..p {
        let off = 12 + s * per_shard;
        let len = u64_at(off);
        let checksum = u64_at(off + 8);
        let peer_counts: Vec<u64> = (0..p).map(|j| u64_at(off + 16 + 8 * j)).collect();
        if peer_counts.iter().sum::<u64>() != len {
            return Err(corrupt(format!(
                "shard {s}: peer_counts sum to {} but len is {len}",
                peer_counts.iter().sum::<u64>()
            )));
        }
        shards.push(ManifestShard {
            len,
            checksum,
            peer_counts,
        });
    }
    Ok(Manifest {
        n,
        p: p as u32,
        shards,
    })
}

// ---------------------------------------------------------------------------
// per-generation run checkpoint (fault-tolerant shuffle recovery)

/// Magic of a persisted run checkpoint.
pub const CHECKPOINT_MAGIC: &[u8; 8] = b"LCCCKPT1";
/// File name of the checkpoint inside a checkpoint directory.
pub const CHECKPOINT_NAME: &str = "checkpoint.lcc";

/// Coordinator-side recovery state at one contraction generation
/// boundary: which graph generation the workers hold custody of (its
/// shard files live in `custody_dir`, in the spill framing), the content
/// hash of the value mirror, the run's RNG stream position, and the
/// transport round counter.  Written via [`write_atomic`] at every
/// custody change — a crash mid-write leaves the previous checkpoint
/// valid.
///
/// Layout: `LCCCKPT1 | generation u64 | machines u32 | mirror u8 |
/// mirror_hash u64 | rng_state 4×u64 | rounds u64 | dir_len u32 |
/// custody_dir | fnv1a64(body) u64`.
#[derive(Debug, Clone, PartialEq)]
pub struct RunCheckpoint {
    /// Generation id of the [`crate::graph::ShardedGraph`] checkpointed.
    pub generation: u64,
    pub machines: u32,
    /// Content hash of the worker value mirror (`None` before any sync).
    pub mirror_hash: Option<u64>,
    /// The run RNG's stream position (Xoshiro256++ state words).
    pub rng_state: [u64; 4],
    /// Transport round counter at the boundary (replayed rounds are
    /// charged once; this pins where the charge log stood).
    pub rounds: u64,
    /// Name of the per-generation shard directory, relative to the
    /// checkpoint directory (`gen-<generation>`).
    pub custody_dir: String,
}

/// Serialize + write a run checkpoint atomically ([`write_atomic`]).
pub fn write_checkpoint(path: &Path, c: &RunCheckpoint) -> Result<(), SpillError> {
    let dir = c.custody_dir.as_bytes();
    let mut body: Vec<u8> = Vec::with_capacity(8 + 4 + 1 + 8 + 32 + 8 + 4 + dir.len());
    body.extend_from_slice(&c.generation.to_le_bytes());
    body.extend_from_slice(&c.machines.to_le_bytes());
    body.push(u8::from(c.mirror_hash.is_some()));
    body.extend_from_slice(&c.mirror_hash.unwrap_or(0).to_le_bytes());
    for w in c.rng_state {
        body.extend_from_slice(&w.to_le_bytes());
    }
    body.extend_from_slice(&c.rounds.to_le_bytes());
    body.extend_from_slice(&(dir.len() as u32).to_le_bytes());
    body.extend_from_slice(dir);
    let mut h = Fnv1a::new();
    h.update(&body);
    let h = h.finish();
    let mut image = Vec::with_capacity(8 + body.len() + 8);
    image.extend_from_slice(CHECKPOINT_MAGIC);
    image.extend_from_slice(&body);
    image.extend_from_slice(&h.to_le_bytes());
    write_atomic(path, &image)
}

/// Read + validate a run checkpoint (magic, exact length, checksum).
pub fn read_checkpoint(path: &Path) -> Result<RunCheckpoint, SpillError> {
    let bytes = fs::read(path).map_err(|e| SpillError::io(path, "read", e))?;
    const FIXED: usize = 8 + 4 + 1 + 8 + 32 + 8 + 4;
    if bytes.len() < 8 + FIXED + 8 {
        return Err(SpillError::Truncated {
            path: path.to_path_buf(),
            expected_bytes: (8 + FIXED + 8) as u64,
            actual_bytes: bytes.len() as u64,
        });
    }
    if &bytes[..8] != CHECKPOINT_MAGIC {
        return Err(SpillError::BadMagic {
            path: path.to_path_buf(),
        });
    }
    let body = &bytes[8..bytes.len() - 8];
    let mut fnv = Fnv1a::new();
    fnv.update(body);
    let h = fnv.finish();
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if h != stored {
        return Err(SpillError::ChecksumMismatch {
            path: path.to_path_buf(),
            expected: stored,
            actual: h,
        });
    }
    let corrupt = |detail: String| SpillError::Corrupt {
        path: path.to_path_buf(),
        detail,
    };
    let u64_at = |off: usize| -> u64 { u64::from_le_bytes(body[off..off + 8].try_into().unwrap()) };
    let generation = u64_at(0);
    let machines = u32::from_le_bytes(body[8..12].try_into().unwrap());
    let mirror_hash = match body[12] {
        0 => None,
        1 => Some(u64_at(13)),
        tag => return Err(corrupt(format!("bad mirror-presence tag {tag}"))),
    };
    let mut rng_state = [0u64; 4];
    for (i, w) in rng_state.iter_mut().enumerate() {
        *w = u64_at(21 + 8 * i);
    }
    let rounds = u64_at(53);
    let dir_len = u32::from_le_bytes(body[61..65].try_into().unwrap()) as usize;
    if body.len() != FIXED + dir_len {
        return Err(corrupt(format!(
            "checkpoint body is {} bytes, inconsistent with dir_len={dir_len}",
            body.len()
        )));
    }
    let custody_dir = std::str::from_utf8(&body[65..])
        .map_err(|_| corrupt("custody dir name is not UTF-8".into()))?
        .to_string();
    Ok(RunCheckpoint {
        generation,
        machines,
        mirror_hash,
        rng_state,
        rounds,
        custody_dir,
    })
}

/// Generation-retention policy for long-lived processes: remove all but
/// the `keep_last` highest-numbered `gen-<id>/` custody directories under
/// `root`, returning how many were removed.
///
/// A bounded batch run cuts a handful of generations and exits; a serve
/// daemon recontracts indefinitely, so without pruning the checkpoint
/// root grows one custody directory (O(edges) of spill files) per
/// contraction generation.  Generation ids are process-monotone
/// ([`crate::graph::ShardedGraph::generation`]), so "the `keep_last`
/// highest ids" is exactly "the `keep_last` most recent snapshots".
/// Removal is best-effort: a directory that cannot be removed (e.g. a
/// concurrent reader holds a file open) is skipped, not an error — a
/// stale generation directory is inert, just disk.
pub fn prune_generations(root: &Path, keep_last: usize) -> usize {
    let keep_last = keep_last.max(1);
    let Ok(entries) = fs::read_dir(root) else {
        return 0;
    };
    let mut gens: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name();
            let id = name
                .to_string_lossy()
                .strip_prefix("gen-")?
                .parse::<u64>()
                .ok()?;
            let path = e.path();
            path.is_dir().then_some((id, path))
        })
        .collect();
    if gens.len() <= keep_last {
        return 0;
    }
    gens.sort_by_key(|&(id, _)| std::cmp::Reverse(id));
    gens.split_off(keep_last)
        .into_iter()
        .filter(|(_, path)| fs::remove_dir_all(path).is_ok())
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> SpillDir {
        SpillDir::create_temp(None).unwrap()
    }

    fn canonical_edges(p: usize, s: usize) -> Vec<(Vertex, Vertex)> {
        // edges whose min endpoint is owned by shard s
        let mut edges: Vec<(Vertex, Vertex)> = (0u32..2000)
            .filter(|&u| machine_of(u as u64, p) == s)
            .map(|u| (u, u + 1 + (u % 7)))
            .collect();
        edges.sort_unstable();
        edges.dedup();
        edges
    }

    #[test]
    fn prune_generations_keeps_last_k() {
        let dir = tmp();
        // N "recontractions" leave N gen dirs plus unrelated entries …
        for id in [3u64, 7, 11, 12, 40] {
            let d = dir.path().join(format!("gen-{id}"));
            fs::create_dir_all(&d).unwrap();
            fs::write(d.join(shard_file_name(0)), b"custody").unwrap();
        }
        fs::create_dir_all(dir.path().join("gen-not-a-number")).unwrap();
        fs::write(dir.path().join(CHECKPOINT_NAME), b"ck").unwrap();
        // … retention keeps the K highest ids and nothing else is touched
        assert_eq!(prune_generations(dir.path(), 2), 3);
        let survivors: Vec<bool> = [3u64, 7, 11, 12, 40]
            .iter()
            .map(|id| dir.path().join(format!("gen-{id}")).is_dir())
            .collect();
        assert_eq!(survivors, [false, false, false, true, true]);
        assert!(dir.path().join("gen-not-a-number").is_dir());
        assert!(dir.path().join(CHECKPOINT_NAME).is_file());
        // idempotent at or under the bound; keep_last=0 still keeps one
        assert_eq!(prune_generations(dir.path(), 2), 0);
        assert_eq!(prune_generations(dir.path(), 0), 1);
        assert!(dir.path().join("gen-40").is_dir());
        // a root that does not exist is a no-op, not a panic
        assert_eq!(prune_generations(&dir.path().join("absent"), 3), 0);
    }

    #[test]
    fn shard_file_roundtrip() {
        let dir = tmp();
        let edges = canonical_edges(4, 1);
        let path = dir.path().join(shard_file_name(1));
        let ck = write_shard_file(&path, 1, 4, &edges).unwrap();
        assert_eq!(ck, checksum_edges(&edges));
        validate_shard_file_len(&path, edges.len() as u64).unwrap();
        assert_eq!(read_shard_file(&path, 1, 4).unwrap(), (edges, ck));
    }

    #[test]
    fn shard_bytes_roundtrip_matches_file_framing() {
        // the in-memory wire image IS the file image: encode → write,
        // fs::read → read_shard_bytes must agree with the file path
        let dir = tmp();
        let edges = canonical_edges(4, 2);
        let path = dir.path().join(shard_file_name(2));
        let (bytes, ck) = encode_shard_bytes(2, 4, &edges);
        let file_ck = write_shard_file(&path, 2, 4, &edges).unwrap();
        assert_eq!(ck, file_ck);
        assert_eq!(fs::read(&path).unwrap(), bytes);
        let (decoded, ck2) =
            read_shard_bytes(&bytes, 2, 4, Path::new("<frame>")).unwrap();
        assert_eq!((decoded, ck2), (edges, ck));
        // wrong identity and truncation are typed on the bytes path too
        assert!(matches!(
            read_shard_bytes(&bytes, 0, 4, Path::new("<frame>")),
            Err(SpillError::Corrupt { .. })
        ));
        assert!(matches!(
            read_shard_bytes(&bytes[..bytes.len() - 2], 2, 4, Path::new("<frame>")),
            Err(SpillError::Truncated { .. })
        ));
    }

    #[test]
    fn truncated_file_is_typed_error() {
        let dir = tmp();
        let edges = canonical_edges(4, 0);
        let path = dir.path().join(shard_file_name(0));
        write_shard_file(&path, 0, 4, &edges).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        match read_shard_file(&path, 0, 4) {
            Err(SpillError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
        // header shorter than minimal
        fs::write(&path, &bytes[..10]).unwrap();
        assert!(matches!(
            read_shard_file(&path, 0, 4),
            Err(SpillError::Truncated { .. })
        ));
    }

    #[test]
    fn corrupt_payload_is_checksum_mismatch() {
        let dir = tmp();
        let edges = canonical_edges(4, 2);
        let path = dir.path().join(shard_file_name(2));
        write_shard_file(&path, 2, 4, &edges).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        // flip a dst-column byte (the columns are what the checksum covers)
        let mid = V2_HEADER_BYTES as usize + edges.len() * 4;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_shard_file(&path, 2, 4),
            Err(SpillError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn corrupt_index_bucket_is_typed_corrupt() {
        // the logical checksum does not cover the index bytes, so index
        // damage must be caught by the rebuild-and-compare walk instead
        let dir = tmp();
        let edges = canonical_edges(4, 2);
        let path = dir.path().join(shard_file_name(2));
        write_shard_file(&path, 2, 4, &edges).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // last index offset no longer equals m
        fs::write(&path, &bytes).unwrap();
        match read_shard_file(&path, 2, 4) {
            Err(SpillError::Corrupt { detail, .. }) => {
                assert!(detail.contains("index bucket"), "{detail}")
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // a lying bucket *count* is typed before any offset is trusted
        let mut bytes = fs::read(&path).unwrap();
        bytes[32] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_shard_file(&path, 2, 4),
            Err(SpillError::Corrupt { .. })
        ));
    }

    #[test]
    fn legacy_row_major_framing_still_reads() {
        // a v1 image (what pre-columnar generations persisted): header +
        // row-major pairs, no index — must parse, verify, and iterate
        let edges = canonical_edges(4, 1);
        let checksum = checksum_edges(&edges);
        let mut v1 = Vec::new();
        v1.extend_from_slice(SHARD_MAGIC);
        v1.extend_from_slice(&1u32.to_le_bytes());
        v1.extend_from_slice(&4u32.to_le_bytes());
        v1.extend_from_slice(&(edges.len() as u64).to_le_bytes());
        v1.extend_from_slice(&checksum.to_le_bytes());
        for &(u, v) in &edges {
            v1.extend_from_slice(&u.to_le_bytes());
            v1.extend_from_slice(&v.to_le_bytes());
        }
        let (decoded, ck) = read_shard_bytes(&v1, 1, 4, Path::new("<v1>")).unwrap();
        assert_eq!((decoded, ck), (edges.clone(), checksum));
        // and the cursor answers vertex_range by binary search
        let (cursor, _) = parse_shard_image(&v1, 1, 4, Path::new("<v1>")).unwrap();
        for &(u, _) in &edges {
            let r = cursor.vertex_range(u);
            assert!(!r.is_empty());
            for i in r {
                assert_eq!(cursor.get(i).0, u);
            }
        }
        // a legacy file on disk reloads through the store path too
        let dir = tmp();
        let path = dir.path().join(shard_file_name(1));
        fs::write(&path, &v1).unwrap();
        validate_shard_file_len(&path, edges.len() as u64).unwrap();
        assert_eq!(read_shard_file(&path, 1, 4).unwrap(), (edges, checksum));
    }

    #[test]
    fn cursor_index_brackets_every_vertex() {
        let edges = canonical_edges(4, 2);
        let (bytes, _) = encode_shard_bytes(2, 4, &edges);
        let (cursor, _) = parse_shard_image(&bytes, 2, 4, Path::new("<mem>")).unwrap();
        assert_eq!(cursor.len(), edges.len());
        assert_eq!(cursor.iter().collect::<Vec<_>>(), edges);
        // every present source maps to exactly its rows; absent ones to none
        let max_src = edges.iter().map(|&(u, _)| u).max().unwrap();
        for v in 0..=max_src + 3 {
            let expect: Vec<usize> = (0..edges.len()).filter(|&i| edges[i].0 == v).collect();
            let got: Vec<usize> = cursor.vertex_range(v).collect();
            assert_eq!(got, expect, "vertex {v}");
        }
    }

    #[test]
    fn cursor_slices_match_full_iteration() {
        let edges = canonical_edges(4, 0);
        let (bytes, _) = encode_shard_bytes(0, 4, &edges);
        let (cursor, _) = parse_shard_image(&bytes, 0, 4, Path::new("<mem>")).unwrap();
        let m = cursor.len();
        for (lo, hi) in [(0, m), (0, m / 2), (m / 2, m), (m / 3, 2 * m / 3), (m, m)] {
            let got: Vec<_> = cursor.slice(lo, hi).iter().collect();
            assert_eq!(got, edges[lo..hi].to_vec(), "slice {lo}..{hi}");
        }
        // sliced cursors still answer vertex_range (by binary search)
        let half = cursor.slice(0, m / 2);
        let (u0, _) = edges[0];
        assert_eq!(
            half.vertex_range(u0).collect::<Vec<_>>(),
            (0..m / 2).filter(|&i| edges[i].0 == u0).collect::<Vec<_>>()
        );
    }

    #[test]
    fn misaligned_image_offset_parses_identically() {
        // frame bodies land at arbitrary offsets inside receive buffers;
        // the cursor must not care about the image's alignment
        let edges = canonical_edges(4, 3);
        let (bytes, ck) = encode_shard_bytes(3, 4, &edges);
        for pad in 1..8usize {
            let mut buf = vec![0u8; pad];
            buf.extend_from_slice(&bytes);
            let (cursor, got_ck) =
                parse_shard_image(&buf[pad..], 3, 4, Path::new("<frame>")).unwrap();
            assert_eq!(got_ck, ck);
            assert_eq!(cursor.iter().collect::<Vec<_>>(), edges, "pad {pad}");
        }
    }

    #[test]
    fn empty_shard_roundtrips_without_index() {
        let dir = tmp();
        let path = dir.path().join(shard_file_name(0));
        write_shard_file(&path, 0, 4, &[]).unwrap();
        assert_eq!(
            fs::metadata(&path).unwrap().len(),
            V2_HEADER_BYTES,
            "empty shard is header-only"
        );
        validate_shard_file_len(&path, 0).unwrap();
        let (edges, _) = read_shard_file(&path, 0, 4).unwrap();
        assert!(edges.is_empty());
    }

    #[test]
    fn spilled_reads_are_mapped_not_copied() {
        let dir = tmp();
        let p = 4;
        let shards: Vec<SpilledShard> = (0..p)
            .map(|s| {
                let shard = EdgeShard::new_canonical(canonical_edges(p, s), p, s);
                spill_shard(&dir, s, p, &shard).unwrap()
            })
            .collect();
        let edges0 = canonical_edges(p, 0);
        let dir = std::sync::Arc::new(dir);
        let store = Spilled::from_parts(dir, shards);
        let before = data_plane_counters();
        let first = store.read(0).unwrap();
        assert!(matches!(first, ShardData::Mapped { .. }));
        assert_eq!(first.iter().collect::<Vec<_>>(), edges0);
        let p1 = first.image().unwrap().as_ptr();
        // later reads reuse the cached validated mapping (same bytes)
        let again = store.read(0).unwrap();
        assert_eq!(again.image().unwrap().as_ptr(), p1);
        // counters are process-global (other tests run concurrently), so
        // only monotonicity is asserted here
        let after = data_plane_counters();
        #[cfg(unix)]
        assert!(after.shard_maps > before.shard_maps);
        #[cfg(not(unix))]
        assert!(after.shard_copies > before.shard_copies);
    }

    #[test]
    fn read_buf_capacity_is_capped_after_oversized_read() {
        // one giant staging read must not pin its high-water capacity in
        // the thread-local buffer for the rest of the run
        let dir = tmp();
        let path = dir.path().join("big.raw");
        let pairs: Vec<(Vertex, Vertex)> = (0..(READ_BUF_RETAIN as u32 / 8 + 1024))
            .map(|i| (i, i + 1))
            .collect();
        let mut image = Vec::new();
        crate::graph::io::write_pairs(&mut image, &pairs).unwrap();
        assert!(image.len() > READ_BUF_RETAIN);
        fs::write(&path, &image).unwrap();
        let got = read_raw_pairs(&path, image.len() as u64).unwrap();
        assert_eq!(got.len(), pairs.len());
        READ_BUF.with(|b| {
            assert!(
                b.borrow().capacity() <= READ_BUF_RETAIN,
                "retained {} > cap {READ_BUF_RETAIN}",
                b.borrow().capacity()
            );
        });
    }

    #[test]
    fn wrong_identity_and_magic_are_typed() {
        let dir = tmp();
        let edges = canonical_edges(4, 3);
        let path = dir.path().join(shard_file_name(3));
        write_shard_file(&path, 3, 4, &edges).unwrap();
        assert!(matches!(
            read_shard_file(&path, 1, 4),
            Err(SpillError::Corrupt { .. })
        ));
        let mut bytes = fs::read(&path).unwrap();
        bytes[0] = b'X';
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_shard_file(&path, 3, 4),
            Err(SpillError::BadMagic { .. })
        ));
    }

    #[test]
    fn missing_file_is_io_error() {
        let dir = tmp();
        let path = dir.path().join(shard_file_name(0));
        match read_shard_file(&path, 0, 1) {
            Err(SpillError::Io { op, .. }) => assert_eq!(op, "open"),
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn manifest_roundtrip_and_corruption() {
        let dir = tmp();
        let m = Manifest {
            n: 100,
            p: 2,
            shards: vec![
                ManifestShard {
                    len: 3,
                    checksum: 7,
                    peer_counts: vec![1, 2],
                },
                ManifestShard {
                    len: 0,
                    checksum: 9,
                    peer_counts: vec![0, 0],
                },
            ],
        };
        let path = dir.path().join(MANIFEST_NAME);
        write_manifest(&path, &m).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m);
        let mut bytes = fs::read(&path).unwrap();
        bytes[10] ^= 1;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_manifest(&path),
            Err(SpillError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn checkpoint_roundtrip_and_corruption() {
        let dir = tmp();
        let c = RunCheckpoint {
            generation: 42,
            machines: 4,
            mirror_hash: Some(0xdead_beef_cafe_f00d),
            rng_state: [1, 2, 3, u64::MAX],
            rounds: 17,
            custody_dir: "gen-42".into(),
        };
        let path = dir.path().join(CHECKPOINT_NAME);
        write_checkpoint(&path, &c).unwrap();
        assert_eq!(read_checkpoint(&path).unwrap(), c);

        // no mirror yet
        let c2 = RunCheckpoint {
            mirror_hash: None,
            ..c.clone()
        };
        write_checkpoint(&path, &c2).unwrap();
        assert_eq!(read_checkpoint(&path).unwrap(), c2);

        // corruption is a typed checksum mismatch
        let mut bytes = fs::read(&path).unwrap();
        bytes[20] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::ChecksumMismatch { .. })
        ));
        // foreign file / truncation are typed too
        fs::write(&path, b"not a checkpoint").unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::Truncated { .. })
        ));
        fs::write(&path, [b"XXXXXXXX".as_slice(), &[0u8; 80]].concat()).unwrap();
        assert!(matches!(
            read_checkpoint(&path),
            Err(SpillError::BadMagic { .. })
        ));
    }

    #[test]
    fn atomic_write_replaces_and_survives_stale_tmp() {
        let dir = tmp();
        let path = dir.path().join("target.bin");
        write_atomic(&path, b"first image").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first image");
        // a stale tmp from a crashed previous writer must not break the
        // next write — it is simply overwritten and renamed away
        fs::write(dir.path().join("target.bin.tmp"), b"torn garbage").unwrap();
        write_atomic(&path, b"second image").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second image");
        assert!(
            !dir.path().join("target.bin.tmp").exists(),
            "tmp renamed into place"
        );
    }

    #[test]
    fn spill_dir_removed_on_drop_but_adopted_kept() {
        let dir = tmp();
        let path = dir.path().to_path_buf();
        fs::write(path.join("x"), b"y").unwrap();
        drop(dir);
        assert!(!path.exists());

        let keep = std::env::temp_dir().join(format!("lcc-spill-keep-{}", std::process::id()));
        fs::create_dir_all(&keep).unwrap();
        drop(SpillDir::adopt(keep.clone()));
        assert!(keep.exists());
        let _ = fs::remove_dir_all(&keep);
    }
}
