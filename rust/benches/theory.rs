//! `cargo bench --bench theory` — all theory-validation experiments:
//! L4.1 decay, L4.5 depth, T5.5 loglog, T7.1/7.2 path bounds, O(m) comm,
//! and the YV17 cycles instance.

fn main() {
    let seed = 42;
    let _ = std::fs::create_dir_all("bench_results");
    for (name, (text, json)) in [
        ("decay (Lemma 4.1)", lcc::bench::theory::decay(seed)),
        ("depth (Lemma 4.5)", lcc::bench::theory::depth(seed)),
        ("loglog (Theorem 5.5)", lcc::bench::theory::loglog(seed)),
        ("path (Theorems 7.1/7.2)", lcc::bench::theory::path_lower_bound(seed)),
        ("comm (§1.1 O(m))", lcc::bench::theory::comm(seed, None)),
        ("cycles (YV17)", lcc::bench::theory::cycles(seed)),
    ] {
        println!("=== theory: {name} ===");
        println!("{text}");
        let file = format!(
            "bench_results/theory_{}.json",
            json.get("exp").and_then(|e| e.as_str()).unwrap_or("x")
        );
        std::fs::write(file, json.pretty()).ok();
    }
}
