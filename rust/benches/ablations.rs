//! `cargo bench --bench ablations` — design-choice ablations: §6 finisher
//! threshold + isolated-node pruning, §5 MergeToLarge schedule, MPC
//! machine scaling, and the compiled dense backend on/off.

fn main() {
    let seed = 42;
    let _ = std::fs::create_dir_all("bench_results");
    for (name, (text, json)) in [
        ("finisher threshold (§6)", lcc::bench::ablations::finisher(seed)),
        ("isolated-node pruning (§6)", lcc::bench::ablations::pruning(seed)),
        ("MergeToLarge schedule (§5)", lcc::bench::ablations::mtl_schedule(seed)),
        ("machine scaling (§2.1)", lcc::bench::ablations::machines(seed)),
        ("dense XLA backend", lcc::bench::ablations::dense_backend(seed)),
    ] {
        println!("=== ablation: {name} ===");
        println!("{text}");
        let file = format!(
            "bench_results/ablation_{}.json",
            json.get("exp").and_then(|e| e.as_str()).unwrap_or("x")
        );
        std::fs::write(file, json.pretty()).ok();
    }
}
