//! `cargo bench --bench table2` — regenerate Table 2 (phases per
//! algorithm x dataset, median of 3 seeds, "X" = resource guard tripped).
//! Scale with LCC_BENCH_SCALE (default 20000 for bench runtime sanity).

fn main() {
    let cfg = lcc::bench::tables::SweepConfig {
        scale: std::env::var("LCC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).or(Some(20_000)),
        ..Default::default()
    };
    let reports = lcc::bench::tables::sweep(&cfg);
    let (text, json) = lcc::bench::tables::table2(&reports);
    println!("=== Table 2: numbers of phases used by each algorithm ===");
    println!("{text}");
    let _ = std::fs::create_dir_all("bench_results");
    std::fs::write("bench_results/table2.json", json.pretty()).ok();
}
