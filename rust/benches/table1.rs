//! `cargo bench --bench table1` — regenerate Table 1 (dataset inventory).
//! Scale with LCC_BENCH_SCALE (default: preset defaults).

fn scale() -> Option<usize> {
    std::env::var("LCC_BENCH_SCALE").ok().and_then(|s| s.parse().ok())
}

fn main() {
    let cfg = lcc::bench::tables::SweepConfig {
        scale: scale(),
        ..Default::default()
    };
    let (text, json) = lcc::bench::tables::table1(&cfg);
    println!("=== Table 1: graphs used in the empirical study (analogues) ===");
    println!("{text}");
    let _ = std::fs::create_dir_all("bench_results");
    std::fs::write("bench_results/table1.json", json.pretty()).ok();
}
