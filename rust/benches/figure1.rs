//! `cargo bench --bench figure1` — regenerate Figure 1 (edges at the
//! beginning of each phase; the >=10x decay observation).
//! Scale with LCC_BENCH_SCALE (default 50000).

fn main() {
    let cfg = lcc::bench::tables::SweepConfig {
        scale: std::env::var("LCC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).or(Some(50_000)),
        ..Default::default()
    };
    let (text, json) = lcc::bench::tables::figure1(&cfg, &["clueweb", "webpages"]);
    println!("=== Figure 1: numbers of edges at the beginning of each iteration ===");
    println!("{text}");
    let _ = std::fs::create_dir_all("bench_results");
    std::fs::write("bench_results/figure1.json", json.pretty()).ok();
}
