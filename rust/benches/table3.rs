//! `cargo bench --bench table3` — regenerate Table 3 (relative running
//! times, normalized per dataset to the fastest algorithm, median of 3).
//! Scale with LCC_BENCH_SCALE (default 20000).

fn main() {
    let cfg = lcc::bench::tables::SweepConfig {
        scale: std::env::var("LCC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).or(Some(20_000)),
        ..Default::default()
    };
    let reports = lcc::bench::tables::sweep(&cfg);
    let (text, json) = lcc::bench::tables::table3(&reports);
    println!("=== Table 3: relative running times ===");
    println!("{text}");
    let _ = std::fs::create_dir_all("bench_results");
    std::fs::write("bench_results/table3.json", json.pretty()).ok();
}
