//! `cargo bench --bench perf` — §Perf micro-benchmarks across all layers
//! (see EXPERIMENTS.md §Perf for the iteration log and targets).
//! LCC_BENCH_QUICK=1 for a fast pass; LCC_BENCH_MACHINES=N to sweep the
//! shard count (default 16); LCC_BENCH_SPILL_BUDGET=BYTES to run the
//! sharded benches out-of-core.

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let machines = std::env::var("LCC_BENCH_MACHINES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let spill_budget = std::env::var("LCC_BENCH_SPILL_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok());
    println!(
        "=== §Perf micro-benchmarks (quick={quick}, machines={machines}, \
         spill_budget={spill_budget:?}) ==="
    );
    // always in-process here: the bench binary cannot serve `lcc worker`,
    // so the proc-transport row is exclusive to `lcc perf --transport proc`
    for m in lcc::bench::perf::standard_suite(
        quick,
        machines,
        spill_budget,
        lcc::mpc::TransportMode::InProc,
    ) {
        println!("{}", m.report_line());
    }
}
