//! `cargo bench --bench perf` — §Perf micro-benchmarks across all layers
//! (see EXPERIMENTS.md §Perf for the iteration log and targets).
//! LCC_BENCH_QUICK=1 for a fast pass.

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    println!("=== §Perf micro-benchmarks (quick={quick}) ===");
    for m in lcc::bench::perf::standard_suite(quick) {
        println!("{}", m.report_line());
    }
}
