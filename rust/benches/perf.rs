//! `cargo bench --bench perf` — §Perf micro-benchmarks across all layers
//! (see EXPERIMENTS.md §Perf for the iteration log and targets).
//! LCC_BENCH_QUICK=1 for a fast pass; LCC_BENCH_MACHINES=N to sweep the
//! shard count (default 16).

fn main() {
    let quick = std::env::var("LCC_BENCH_QUICK").is_ok();
    let machines = std::env::var("LCC_BENCH_MACHINES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    println!("=== §Perf micro-benchmarks (quick={quick}, machines={machines}) ===");
    for m in lcc::bench::perf::standard_suite(quick, machines) {
        println!("{}", m.report_line());
    }
}
