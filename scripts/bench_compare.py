#!/usr/bin/env python3
"""Diff a fresh `lcc perf` JSON artifact against checked-in baselines.

Usage: bench_compare.py FRESH.json BASELINE.json [BASELINE2.json ...]

Gate: fail (exit 1) on a >25% regression in any of
  * wall time  — a bench's `median_s` vs the same-named bench in a
    baseline,
  * rounds     — the `round_breakdown.rounds` count of a run recorded in
    both artifacts for the same algo/machines/transport, or
  * peak RSS   — `peak_rss_bytes` when both artifacts carry a measured
    value.  The field is `null` (or absent in pre-PR8 artifacts) on
    platforms without /proc VmHWM; such pairs are skipped with a note,
    never compared against 0.
  * mesh bytes — `round_breakdown.mesh.{sync_bytes,mesh_bytes}` when both
    artifacts record the same shuffle run (same algo/machines/transport):
    a sync-byte blow-up means the delta mirror path stopped engaging.
  * thread sweep — within the FRESH artifact alone (`--thread-sweep`
    rows): a multi-threaded row's summed generate or fold wall-clock
    must not exceed the single-threaded row of the same run by >25%.
    Same machine, same artifact, same run — the only thread-scaling
    comparison that is hardware-apples-to-apples, so it needs no
    baseline and never disarms.

Baselines that are missing or still `pending-first-measurement` produce a
warning and exit 0 — the gate arms itself the first time CI lands real
numbers in BENCH_PR*.json (scripts/publish_bench.py checks them in).
Once ANY baseline carries measurements the gate is strict: zero
overlapping benches with every measured baseline is itself a failure
(renaming the whole suite must update the baselines in the same change,
not silently disarm the gate).
"""

import json
import sys

THRESHOLD = 1.25


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: cannot read {path}: {e}", file=sys.stderr)
        return None


def bench_index(doc):
    """name -> median_s for measured benches (skip non-numeric/zero)."""
    out = {}
    for b in doc.get("benches", []):
        name, median = b.get("name"), b.get("median_s")
        if isinstance(name, str) and isinstance(median, (int, float)) and median > 0:
            out[name] = float(median)
    return out


def peak_rss(doc):
    """Measured peak RSS in bytes, or None when unavailable.

    `peak_rss_bytes` is null when the platform can't report VmHWM and
    absent in artifacts predating the field; both mean "no measurement",
    as does a non-positive value (the old conflated-with-0 encoding).
    """
    rss = doc.get("peak_rss_bytes")
    if isinstance(rss, (int, float)) and rss > 0:
        return float(rss)
    return None


def breakdown_key(doc):
    bd = doc.get("round_breakdown")
    if not isinstance(bd, dict):
        return None, None
    key = (bd.get("algo"), bd.get("machines"), bd.get("transport"))
    rounds = bd.get("rounds")
    return key, len(rounds) if isinstance(rounds, list) else None


def mesh_counters(doc):
    """round_breakdown.mesh dict, or None off the shuffle transport."""
    bd = doc.get("round_breakdown")
    mesh = bd.get("mesh") if isinstance(bd, dict) else None
    return mesh if isinstance(mesh, dict) else None


def check_thread_sweep(doc):
    """Same-artifact gate on `thread_sweep` rows.

    Returns (comparisons, regressions): each threads>1 row's gen_ms and
    fold_ms vs the threads=1 row of the same sweep.  Phases measured at
    ~0ms on either side are skipped (timer granularity, not scaling), as
    is the whole check when the artifact has no sweep or no baseline row
    — this gate only ever fires on data measured seconds apart on the
    same host.
    """
    rows = doc.get("thread_sweep")
    if not isinstance(rows, list):
        return 0, []
    serial = next(
        (r for r in rows if isinstance(r, dict) and r.get("worker_threads") == 1),
        None,
    )
    if serial is None:
        return 0, []
    compared, regressions = 0, []
    for row in rows:
        if not isinstance(row, dict) or row.get("worker_threads") == 1:
            continue
        threads = row.get("worker_threads")
        for key in ("gen_ms", "fold_ms"):
            fv, bv = row.get(key), serial.get(key)
            measurable = (
                isinstance(fv, (int, float))
                and isinstance(bv, (int, float))
                and bv > 1.0  # sub-ms serial phases are all noise
            )
            if not measurable:
                continue
            compared += 1
            if fv > bv * THRESHOLD:
                regressions.append(
                    f"thread sweep {key} at {threads} threads: {fv:.1f}ms vs "
                    f"{bv:.1f}ms single-threaded (same artifact) — {fv / bv:.2f}x"
                )
    return compared, regressions


def main(argv):
    if len(argv) < 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    fresh_path, baseline_paths = argv[1], argv[2:]
    fresh = load(fresh_path)
    if fresh is None:
        print("bench_compare: no fresh artifact; nothing to gate", file=sys.stderr)
        return 1
    fresh_benches = bench_index(fresh)
    fresh_bd_key, fresh_rounds = breakdown_key(fresh)

    # Same-artifact thread-sweep gate: independent of the baselines, so
    # it is tallied separately and never feeds the strict-mode overlap
    # check below (which is about baseline coverage, not self-checks).
    sweep_compared, regressions = check_thread_sweep(fresh)
    compared = 0
    measured_baselines = 0
    for path in baseline_paths:
        base = load(path)
        if base is None:
            print(f"bench_compare: WARNING: baseline {path} missing — skipped")
            continue
        if base.get("status") == "pending-first-measurement" or not base.get("benches"):
            print(
                f"bench_compare: WARNING: baseline {path} has no measurements yet "
                "(pending) — skipped"
            )
            continue
        measured_baselines += 1
        for name, base_median in bench_index(base).items():
            if name not in fresh_benches:
                continue
            compared += 1
            ratio = fresh_benches[name] / base_median
            if ratio > THRESHOLD:
                regressions.append(
                    f"{name}: {fresh_benches[name]:.4f}s vs baseline "
                    f"{base_median:.4f}s ({path}) — {ratio:.2f}x"
                )
        fresh_rss, base_rss = peak_rss(fresh), peak_rss(base)
        if fresh_rss is not None and base_rss is not None:
            compared += 1
            if fresh_rss > base_rss * THRESHOLD:
                regressions.append(
                    f"peak RSS: {fresh_rss / 2**20:.1f}MiB vs baseline "
                    f"{base_rss / 2**20:.1f}MiB ({path}) — {fresh_rss / base_rss:.2f}x"
                )
        elif base_rss is not None or fresh_rss is not None:
            print(
                f"bench_compare: note: peak_rss_bytes unavailable in "
                f"{'fresh artifact' if fresh_rss is None else path} — RSS not compared"
            )
        base_bd_key, base_rounds = breakdown_key(base)
        if (
            base_bd_key is not None
            and base_bd_key == fresh_bd_key
            and base_rounds
            and fresh_rounds
        ):
            compared += 1
            if fresh_rounds > base_rounds * THRESHOLD:
                regressions.append(
                    f"round count: {fresh_rounds} vs baseline {base_rounds} "
                    f"({path}) — {fresh_rounds / base_rounds:.2f}x"
                )
        fresh_mesh, base_mesh = mesh_counters(fresh), mesh_counters(base)
        if base_bd_key is not None and base_bd_key == fresh_bd_key and fresh_mesh and base_mesh:
            for key in ("sync_bytes", "mesh_bytes"):
                fv, bv = fresh_mesh.get(key), base_mesh.get(key)
                if isinstance(fv, (int, float)) and isinstance(bv, (int, float)) and bv > 0:
                    compared += 1
                    if fv > bv * THRESHOLD:
                        regressions.append(
                            f"mesh {key}: {fv} vs baseline {bv} ({path}) — "
                            f"{fv / bv:.2f}x"
                        )

    if compared == 0:
        if measured_baselines > 0:
            # strict mode: a measured baseline exists but shares nothing
            # with the fresh artifact — the gate must not silently disarm
            # (self-contained sweep comparisons don't count as overlap)
            print(
                "bench_compare: FAIL: baselines carry measurements but none "
                "overlap the fresh artifact; update BENCH_PR*.json in the "
                "same change that renamed the suite"
            )
            return 1
        if sweep_compared == 0:
            print(
                "bench_compare: WARNING: no comparable measurements in any baseline — "
                "no-op until CI fills BENCH_PR*.json"
            )
            return 0
    if regressions:
        print(f"bench_compare: {len(regressions)} regression(s) over 25%:")
        for r in regressions:
            print(f"  REGRESSION {r}")
        return 1
    print(
        f"bench_compare: OK — {compared} baseline and {sweep_compared} "
        "thread-sweep comparison(s), none above 25%"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
