#!/usr/bin/env python3
"""Publish measured `lcc perf` artifacts as checked-in baselines.

Usage: publish_bench.py [--root DIR]

Run from CI after the bench jobs have produced fresh artifacts at the
repo root (BENCH_PR2.json from scripts/tier1.sh, BENCH_SPILL.json from
the spill job, BENCH_TRANSPORT.json from the distributed job).  For each
artifact that carries real measurements (a non-empty `benches` array)
this script:

  1. normalizes it (stable key order, `status: measured`, provenance
     stamp from $GITHUB_SHA when set) and writes it back in place, so the
     checked-in file IS the measured baseline the next run's
     scripts/bench_compare.py gate diffs against;
  2. seeds BENCH_PR1.json the first time: while it still says
     `pending-first-measurement` it is replaced by the earliest measured
     BENCH_PR2.json, arming the two-baseline regression gate;
  3. regenerates the measured-trajectory table in EXPERIMENTS.md between
     the `<!-- BENCH:BEGIN -->` / `<!-- BENCH:END -->` markers.

Idempotent: running it twice over the same artifacts is a no-op.  Exits
0 whether or not anything changed (the CI job decides whether to commit
by checking `git diff`); exits 1 only on malformed artifacts.
"""

import argparse
import json
import os
import sys

ARTIFACTS = [
    "BENCH_PR2.json",
    "BENCH_SPILL.json",
    "BENCH_TRANSPORT.json",
    "BENCH_MESH.json",
]
SEED_BASELINE = "BENCH_PR1.json"
EXPERIMENTS = "EXPERIMENTS.md"
BEGIN, END = "<!-- BENCH:BEGIN -->", "<!-- BENCH:END -->"


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return None
    except json.JSONDecodeError as e:
        print(f"publish_bench: malformed {path}: {e}", file=sys.stderr)
        raise SystemExit(1)


def measured(doc):
    return bool(doc) and bool(doc.get("benches")) and doc.get(
        "status"
    ) != "pending-first-measurement"


def write_json(path, doc):
    text = json.dumps(doc, indent=2) + "\n"
    try:
        with open(path) as f:
            if f.read() == text:
                return False
    except OSError:
        pass
    with open(path, "w") as f:
        f.write(text)
    return True


def stamp(doc):
    doc["status"] = "measured"
    doc.pop("note", None)
    doc.pop("schema", None)
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        doc["measured_at_commit"] = sha
    return doc


def trajectory_table(root):
    """One markdown table row per bench per artifact, plus the data-plane
    counters the zero-copy gate watches."""
    lines = [
        "| artifact | bench | median_s | p95_s | throughput/s |",
        "|---|---|---:|---:|---:|",
    ]
    rows = 0
    dp_lines = []
    for name in [SEED_BASELINE] + ARTIFACTS:
        doc = load(os.path.join(root, name))
        if not measured(doc):
            continue
        for b in doc.get("benches", []):
            tp = b.get("throughput_units_per_s")
            lines.append(
                "| {} | {} | {:.4f} | {:.4f} | {} |".format(
                    name,
                    b.get("name", "?"),
                    b.get("median_s", float("nan")),
                    b.get("p95_s", float("nan")),
                    f"{tp:.3e}" if isinstance(tp, (int, float)) else "n/a",
                )
            )
            rows += 1
        dp = doc.get("data_plane")
        if isinstance(dp, dict):
            dp_lines.append(
                "- `{}` data plane: {} bytes mapped in {} map(s), "
                "{} bytes copied in {} copy(ies), {} allocations".format(
                    name,
                    dp.get("shard_bytes_mapped", 0),
                    dp.get("shard_maps", 0),
                    dp.get("shard_bytes_copied", 0),
                    dp.get("shard_copies", 0),
                    dp.get("allocs", 0),
                )
            )
        mesh = (doc.get("round_breakdown") or {}).get("mesh")
        if isinstance(mesh, dict):
            dp_lines.append(
                "- `{}` mesh data plane: {} sync bytes over {} sync(s) "
                "({} delta), {} worker-mesh bytes, {} hop(s) in "
                "{} batch(es), {} rewire(s)".format(
                    name,
                    mesh.get("sync_bytes", 0),
                    mesh.get("state_syncs", 0),
                    mesh.get("delta_syncs", 0),
                    mesh.get("mesh_bytes", 0),
                    mesh.get("hops", 0),
                    mesh.get("hop_batches", 0),
                    mesh.get("rewires", 0),
                )
            )
    if rows == 0:
        return None
    out = lines
    if dp_lines:
        out += [""] + dp_lines
    return "\n".join(out)


def update_experiments(root):
    path = os.path.join(root, EXPERIMENTS)
    try:
        with open(path) as f:
            text = f.read()
    except OSError:
        print(f"publish_bench: WARNING: no {EXPERIMENTS}; table skipped")
        return False
    if BEGIN not in text or END not in text:
        print(f"publish_bench: WARNING: {EXPERIMENTS} has no {BEGIN} markers")
        return False
    table = trajectory_table(root)
    if table is None:
        return False
    head, rest = text.split(BEGIN, 1)
    _, tail = rest.split(END, 1)
    new = head + BEGIN + "\n" + table + "\n" + END + tail
    if new == text:
        return False
    with open(path, "w") as f:
        f.write(new)
    return True


def main(argv):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".")
    args = ap.parse_args(argv[1:])
    root = args.root

    changed = []
    fresh_pr2 = None
    for name in ARTIFACTS:
        path = os.path.join(root, name)
        doc = load(path)
        if not measured(doc):
            print(f"publish_bench: {name}: no measurements — left as is")
            continue
        doc = stamp(doc)
        if name == "BENCH_PR2.json":
            fresh_pr2 = doc
        if write_json(path, doc):
            changed.append(name)

    seed_path = os.path.join(root, SEED_BASELINE)
    seed = load(seed_path)
    if fresh_pr2 is not None and not measured(seed):
        baseline = dict(fresh_pr2)
        baseline["seeded_from"] = "BENCH_PR2.json"
        if write_json(seed_path, baseline):
            changed.append(SEED_BASELINE)
            print("publish_bench: seeded BENCH_PR1.json — regression gate armed")

    if update_experiments(root):
        changed.append(EXPERIMENTS)

    if changed:
        print(f"publish_bench: updated {', '.join(changed)}")
    else:
        print("publish_bench: nothing to publish")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
