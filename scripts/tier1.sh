#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): release build + full test suite + quick perf
# smoke.  The perf smoke writes the machine-readable suite results to
# $BENCH_OUT (default: BENCH_PR2.json, the current PR's tracked artifact)
# at the repo root so the perf trajectory is tracked in version control
# (EXPERIMENTS.md §Perf explains how to read it).
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_OUT="${BENCH_OUT:-BENCH_PR2.json}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found. This gate needs a Rust toolchain; run it" >&2
    echo "tier1: on a toolchain-equipped machine/CI (see EXPERIMENTS.md or" >&2
    echo "tier1: .github/workflows/tier1.yml)." >&2
    exit 1
fi

(cd rust && cargo build --release)
(cd rust && cargo test -q)

# Perf smoke: quick protocol (1 warmup + 3 samples), JSON to the tracked
# artifact.  Runs from the repo root so relative artifact paths resolve.
# --machines sweeps the shard count; 16 is the tracked default.
./rust/target/release/lcc perf --quick --machines 16 --out "$BENCH_OUT"
echo "tier1 OK"
