#!/usr/bin/env python3
"""CI smoke test for `lcc serve`, the incremental connectivity daemon.

Usage: serve_smoke.py [path/to/lcc]   (default: rust/target/release/lcc)

Drives the release binary end to end:
  1. `lcc generate` writes a SNAP-text G(n,p) graph;
  2. `lcc serve --graph file:... --transport shuffle --port 0` brings up
     the persistent worker fleet and announces its ephemeral port;
  3. a from-scratch union-find oracle over the same file checks every
     sampled `component-of` answer bit for bit;
  4. streamed chain insertions cross `--recontract-threshold`, forcing at
     least one full contraction pass over the live fleet;
  5. post-recontraction answers are re-checked against the oracle over
     the accumulated edge multiset, then the daemon is shut down cleanly.

Exit 0 = all checks passed. Any divergence, hang (watchdog timeouts on
every socket op), or unclean daemon exit fails the job.
"""

import json
import os
import socket
import subprocess
import sys
import tempfile

TIMEOUT_S = 120


def fail(msg):
    print(f"serve_smoke: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


class UnionFind:
    def __init__(self, n):
        self.p = list(range(n))

    def find(self, x):
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra

    def canonical_labels(self):
        n = len(self.p)
        mins = {}
        for v in range(n):
            r = self.find(v)
            mins[r] = min(mins.get(r, v), v)
        return [mins[self.find(v)] for v in range(n)]


def load_snap(path):
    """Replicate rust/src/graph/io.rs parse_snap_text: ids remapped to
    dense 0..n in first-seen order."""
    remap, edges = {}, []
    with open(path) as f:
        for line in f:
            t = line.strip()
            if not t or t.startswith("#"):
                continue
            a, b = t.split()[:2]
            u = remap.setdefault(a, len(remap))
            v = remap.setdefault(b, len(remap))
            edges.append((u, v))
    return len(remap), edges


class Client:
    def __init__(self, port):
        self.sock = socket.create_connection(("127.0.0.1", port), timeout=TIMEOUT_S)
        self.rfile = self.sock.makefile("r")

    def request(self, **req):
        self.sock.sendall((json.dumps(req) + "\n").encode())
        line = self.rfile.readline()
        if not line:
            fail(f"daemon hung up on {req}")
        reply = json.loads(line)
        if not reply.get("ok"):
            fail(f"{req} -> {reply}")
        return reply


def main():
    lcc = sys.argv[1] if len(sys.argv) > 1 else "rust/target/release/lcc"
    if not os.path.exists(lcc):
        fail(f"binary {lcc} not found (build with cargo build --release)")

    tmp = tempfile.mkdtemp(prefix="lcc-serve-smoke-")
    graph_path = os.path.join(tmp, "g.txt")
    subprocess.run(
        [lcc, "generate", "--graph", "gnp", "--n", "3000", "--avg-deg", "2",
         "--seed", "7", "--out", graph_path],
        check=True, timeout=TIMEOUT_S,
    )
    n, edges = load_snap(graph_path)
    print(f"serve_smoke: graph n={n} m={len(edges)}")

    daemon = subprocess.Popen(
        [lcc, "serve", "--graph", f"file:{graph_path}", "--machines", "4",
         "--transport", "shuffle", "--port", "0",
         "--recontract-threshold", "16", "--keep-generations", "2"],
        stdout=subprocess.PIPE, text=True,
    )
    try:
        ready = json.loads(daemon.stdout.readline())
        if ready.get("event") != "serving":
            fail(f"unexpected ready line: {ready}")
        if ready.get("n") != n:
            fail(f"daemon sees n={ready.get('n')}, oracle sees n={n}")
        print(f"serve_smoke: daemon up on port {ready['port']} "
              f"(transport={ready.get('transport')})")
        client = Client(ready["port"])

        # bootstrap labels vs the from-scratch oracle
        uf = UnionFind(n)
        for u, v in edges:
            uf.union(u, v)
        labels = uf.canonical_labels()
        sample = range(0, n, 97)
        for u in sample:
            got = client.request(op="component-of", u=u)["label"]
            if got != labels[u]:
                fail(f"component-of({u}) = {got}, oracle says {labels[u]}")
        print(f"serve_smoke: {len(list(sample))} bootstrap queries match the oracle")

        # streamed chain insertions: forces inter-component merges and at
        # least one threshold-triggered recontraction at threshold 16
        for start in range(0, n - 1, 250):
            chain = [[v, v + 1] for v in range(start, min(start + 250, n - 1))]
            client.request(op="insert", edges=chain)
            for u, v in chain:
                uf.union(u, v)
        ack = client.request(op="flush")
        if ack["components"] != 1:
            fail(f"chain must connect everything, got {ack['components']} components")
        if ack["recontractions"] < 1:
            fail(f"expected a threshold-triggered recontraction, got {ack}")
        print(f"serve_smoke: {ack['recontractions']} recontraction(s), "
              f"epoch {ack['epoch']}, {ack['edges']} edges accumulated")

        # post-recontraction answers must be bit-identical to the oracle
        # over the accumulated edge multiset
        labels = uf.canonical_labels()
        for u in sample:
            got = client.request(op="component-of", u=u)["label"]
            if got != labels[u]:
                fail(f"post-recontraction component-of({u}) = {got}, "
                     f"oracle says {labels[u]}")
        same = client.request(op="same-component", u=0, v=n - 1)
        if same["same"] is not True:
            fail(f"0 and {n-1} must be connected after the chain: {same}")
        print("serve_smoke: post-recontraction queries match the oracle")

        client.request(op="shutdown")
        if daemon.wait(timeout=TIMEOUT_S) != 0:
            fail(f"daemon exited {daemon.returncode}")
        print("serve_smoke: OK")
    finally:
        if daemon.poll() is None:
            daemon.kill()
            daemon.wait()


if __name__ == "__main__":
    main()
