#!/usr/bin/env bash
# Fetch the prebuilt xla_extension the `xla` crate's build script links
# against (CPU build) and export its location into $GITHUB_ENV.  Shared
# by every CI job that builds the crate — bump the pinned release here,
# in one place.  If the URL rots, update it from
# https://github.com/elixir-nx/xla/releases (x86_64-linux-gnu-cpu).
set -euo pipefail

XLA_EXT_VERSION="${XLA_EXT_VERSION:-v0.4.4}"
URL="https://github.com/elixir-nx/xla/releases/download/${XLA_EXT_VERSION}/xla_extension-x86_64-linux-gnu-cpu.tar.gz"

mkdir -p "$HOME/xla_extension"
curl -fsSL -o /tmp/xla_extension.tar.gz "$URL"
tar -xzf /tmp/xla_extension.tar.gz -C "$HOME"
echo "XLA_EXTENSION_DIR=$HOME/xla_extension" >> "$GITHUB_ENV"
echo "LD_LIBRARY_PATH=$HOME/xla_extension/lib:${LD_LIBRARY_PATH:-}" >> "$GITHUB_ENV"
